//! The persistent-memory pool: live/shadow word storage, bump allocation,
//! atomic primitives with virtual-time metering, persistence instructions,
//! and full-system crash simulation. See module docs in [`super`].

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crossbeam_utils::CachePadded;

use super::atomic128;
use super::crash::raise_crash;
use super::latency::MeterMode;
use super::layout::{CacheLine, PAddr, WORDS_PER_LINE};
use super::stats::PoolStats;
use super::PmemConfig;
use crate::util::rng::Xoshiro256;
use crate::util::time::spin_ns;

/// Maximum number of worker threads a pool supports (per-thread slots are
/// statically sized; the paper evaluates up to 96 threads).
pub const MAX_THREADS: usize = 128;

/// Declared contention level of a line.
///
/// On this single-core testbed, contention cannot be *observed* (OS
/// scheduling quanta make every line look thread-private while its owner
/// runs), so data structures declare it — which is exactly the paper's own
/// analysis: `Head`/`Tail` are touched by **every** thread per operation
/// (Global); a ring cell is touched by one enqueuer and one dequeuer
/// (Pairwise, the paper's low-contention claim); `Head_i` local copies are
/// single-writer single-reader (Private). The effective accessor count is
/// `min(declared, active_threads)`, so a Global line is uncontended in a
/// single-threaded run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hotness {
    /// Single-writer single-reader (same thread or SWSR): no transfers.
    Private = 0,
    /// A small, rotating set of accessors (ring cells, announce slots).
    Pairwise = 1,
    /// Touched by all active threads (queue endpoints, combining locks).
    Global = 2,
}

/// Per-thread pending-flush queue (`pwb` issued, `psync` not yet executed).
///
/// Invariant: slot `tid` is only accessed by the thread running as `tid`
/// while workers are live, and by the single coordinator thread inside
/// [`PmemPool::crash`] / [`PmemPool::reset_meter`] after all workers have
/// stopped. This is the standard "exclusive logical owner" pattern.
struct PendingSlot {
    lines: UnsafeCell<Vec<u32>>,
    /// Thread-local xorshift state for mask-decay decisions (not security
    /// sensitive; just needs to be cheap).
    decay_rng: UnsafeCell<u64>,
    /// Recently read lines: (line, stamp at read). A load hitting an entry
    /// with an unchanged stamp is a cache hit (local cost) — crucially this
    /// makes spin-waits free until the watched line actually changes, as on
    /// real hardware. RMW/pwb costs do NOT consult this (they use declared
    /// hotness): an RMW on a shared line always transfers.
    read_cache: UnsafeCell<[(u32, u64); READ_CACHE_WAYS]>,
    read_cursor: UnsafeCell<usize>,
}

unsafe impl Sync for PendingSlot {}

/// Per-thread recently-read-lines cache size.
const READ_CACHE_WAYS: usize = 8;

impl PendingSlot {
    fn new(tid: usize) -> Self {
        Self {
            lines: UnsafeCell::new(Vec::with_capacity(16)),
            decay_rng: UnsafeCell::new(0x9E37_79B9 ^ (tid as u64 + 1)),
            read_cache: UnsafeCell::new([(u32::MAX, 0); READ_CACHE_WAYS]),
            read_cursor: UnsafeCell::new(0),
        }
    }
}

/// State shared by every pool of one [`crate::pmem::Topology`] — and owned
/// exclusively by a standalone pool (the degenerate single-socket case).
///
/// * **Virtual clocks** are per *thread*, not per pool: a thread splitting
///   its work across sockets still lives on one timeline (two per-pool
///   clocks would let cross-socket work run "for free" in parallel).
/// * **Crash machinery** is one cut for the whole machine: the step
///   countdown decrements on every primitive of every pool, and the crash
///   flag unwinds threads wherever they are — so a multi-pool crash
///   snapshots all pools at a single point, exactly like a real
///   full-system power failure.
/// * **Thread homes** map each tid to its home socket (assigned by
///   [`crate::util::affinity::place`] round-robin); pools whose socket
///   differs from the caller's home charge the cross-socket cost-model
///   penalties.
pub(crate) struct SharedState {
    vclocks: Vec<CachePadded<AtomicU64>>,
    homes: Vec<std::sync::atomic::AtomicU32>,
    stepping: AtomicBool,
    steps: AtomicI64,
    crash_flag: AtomicBool,
    epoch: AtomicU64,
}

impl SharedState {
    pub(crate) fn new() -> Self {
        Self {
            vclocks: (0..MAX_THREADS).map(|_| CachePadded::new(AtomicU64::new(0))).collect(),
            homes: (0..MAX_THREADS).map(|_| std::sync::atomic::AtomicU32::new(0)).collect(),
            stepping: AtomicBool::new(false),
            steps: AtomicI64::new(i64::MAX),
            crash_flag: AtomicBool::new(false),
            epoch: AtomicU64::new(0),
        }
    }

    /// Thread `tid`'s home socket.
    #[inline]
    pub(crate) fn home_of(&self, tid: usize) -> usize {
        self.homes[tid].load(Ordering::Relaxed) as usize
    }

    /// Assign thread `tid`'s home socket (topology construction;
    /// quiescent).
    pub(crate) fn set_home(&self, tid: usize, socket: usize) {
        self.homes[tid].store(socket as u32, Ordering::Relaxed);
    }

    /// Disarm the countdown, clear the crash flag and bump the epoch —
    /// the coordinated tail of a crash, executed **once** per cut (not
    /// once per pool).
    pub(crate) fn finish_crash(&self) {
        self.stepping.store(false, Ordering::SeqCst);
        self.steps.store(i64::MAX, Ordering::SeqCst);
        self.crash_flag.store(false, Ordering::SeqCst);
        self.epoch.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn arm_crash_after(&self, steps: u64) {
        self.steps.store(steps.min(i64::MAX as u64) as i64, Ordering::SeqCst);
        self.stepping.store(true, Ordering::SeqCst);
    }

    pub(crate) fn crash_now(&self) {
        self.crash_flag.store(true, Ordering::SeqCst);
        // The primitive-entry check only consults the flag while
        // `stepping` is on (the armed-countdown fast path skips all crash
        // bookkeeping otherwise) — enable it so an unarmed `crash_now`
        // actually unwinds threads at their next primitive, as documented.
        self.stepping.store(true, Ordering::SeqCst);
    }

    pub(crate) fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    #[inline]
    pub(crate) fn vtime(&self, tid: usize) -> u64 {
        self.vclocks[tid].load(Ordering::Relaxed)
    }

    pub(crate) fn max_vtime(&self) -> u64 {
        self.vclocks.iter().map(|c| c.load(Ordering::Relaxed)).max().unwrap_or(0)
    }

    pub(crate) fn reset_vclocks(&self) {
        for c in &self.vclocks {
            c.store(0, Ordering::Relaxed);
        }
    }
}

/// The simulated-NVM pool. See [`super`] module docs.
pub struct PmemPool {
    /// Live (cache/DRAM view) storage, 64-byte aligned lines.
    live: Box<[CacheLine]>,
    /// Shadow (NVM view) storage — what survives a crash.
    shadow: Box<[CacheLine]>,
    /// Per-line virtual-time stamp of the last writer/flusher.
    stamps: Box<[AtomicU64]>,
    /// Per-line recent-accessor bitmask (tid mod 64) — statistics only.
    masks: Box<[AtomicU64]>,
    /// Per-line declared contention level (see [`Hotness`]); default
    /// Pairwise.
    hot: Box<[std::sync::atomic::AtomicU8]>,
    /// Active worker thread count (set by the harness; bounds Global
    /// contention).
    active_threads: std::sync::atomic::AtomicU32,
    /// Per-thread pending pwb queues.
    pending: Vec<CachePadded<PendingSlot>>,
    /// Operation counters.
    pub stats: PoolStats,
    /// Bump allocator cursor (word index; word 0 reserved as PNULL).
    next_word: AtomicUsize,
    /// Per-pool NVM write-bandwidth chain: every realized flush appends its
    /// media cost here and joins the flusher — all threads' flushes on
    /// *this* pool share its DIMMs (the effect that lets batch-flushing
    /// combining queues save persistence bandwidth). Independent per pool:
    /// a multi-pool topology has one bandwidth chain per socket.
    nvm_chain: AtomicU64,
    /// Virtual clocks + crash cut + thread homes, shared across a
    /// topology's pools (see [`SharedState`]).
    shared: Arc<SharedState>,
    /// This pool's socket index within its topology (0 standalone).
    socket: usize,
    /// Volatile state of this pool's persistent flight recorder (the
    /// NVM rings live in the arena; see [`crate::obs::flight`]).
    flight: crate::obs::flight::FlightRec,
    /// Volatile state of this pool's size-classed persistent allocator
    /// (segment headers + extent directory live in the arena; see
    /// [`crate::pmem::palloc`]).
    palloc: super::palloc::PallocState,
    cfg: PmemConfig,
}

impl PmemPool {
    /// Create a standalone pool with `cfg.capacity_words` words of
    /// persistent memory (zero-initialized, zero shadow — i.e. freshly
    /// formatted NVM). Standalone = its own [`SharedState`] on socket 0,
    /// the degenerate single-socket topology.
    pub fn new(cfg: PmemConfig) -> Self {
        Self::with_shared(cfg, 0, Arc::new(SharedState::new()))
    }

    /// Create a pool on `socket` sharing a topology's clocks/crash cut
    /// (see [`crate::pmem::Topology`]).
    pub(crate) fn with_shared(cfg: PmemConfig, socket: usize, shared: Arc<SharedState>) -> Self {
        let words = cfg.capacity_words.max(WORDS_PER_LINE * 2);
        let n_lines = words.div_ceil(WORDS_PER_LINE);
        let mk = |n: usize| -> Box<[CacheLine]> {
            (0..n).map(|_| CacheLine::zeroed()).collect::<Vec<_>>().into_boxed_slice()
        };
        let mk_atoms =
            |n: usize| -> Box<[AtomicU64]> { (0..n).map(|_| AtomicU64::new(0)).collect() };
        let pool = Self {
            live: mk(n_lines),
            shadow: mk(n_lines),
            stamps: mk_atoms(n_lines),
            masks: mk_atoms(n_lines),
            hot: (0..n_lines)
                .map(|_| std::sync::atomic::AtomicU8::new(Hotness::Pairwise as u8))
                .collect(),
            active_threads: std::sync::atomic::AtomicU32::new(2),
            pending: (0..MAX_THREADS).map(|t| CachePadded::new(PendingSlot::new(t))).collect(),
            stats: PoolStats::new(MAX_THREADS),
            next_word: AtomicUsize::new(1), // word 0 = PNULL, reserved
            nvm_chain: AtomicU64::new(0),
            shared,
            socket,
            flight: crate::obs::flight::FlightRec::new(),
            palloc: super::palloc::PallocState::new(),
            cfg,
        };
        // The flight-recorder directory is carved first so it lands at
        // the well-known `flight::DIR_BASE` (no-op on tiny arenas); the
        // allocator's extent directory follows it.
        crate::obs::flight::carve_dir(&pool);
        super::palloc::carve_dir(&pool);
        pool
    }

    /// The pool configuration.
    pub fn config(&self) -> &PmemConfig {
        &self.cfg
    }

    /// The socket (topology pool index) this pool lives on.
    pub fn socket(&self) -> usize {
        self.socket
    }

    /// The clock/crash state shared with this pool's topology siblings.
    pub(crate) fn shared(&self) -> &Arc<SharedState> {
        &self.shared
    }

    /// Current crash epoch (number of crashes so far — topology-wide).
    pub fn epoch(&self) -> u64 {
        self.shared.epoch()
    }

    // ------------------------------------------------------------------
    // Allocation
    // ------------------------------------------------------------------

    /// Bump-allocate `n` words aligned to `align` words. Panics (hard error,
    /// not a simulated crash) on exhaustion — size the pool via
    /// `PmemConfig::capacity_words`. Operation-time allocation (anything
    /// that can run mid-enqueue) must use [`Self::try_alloc`] or the
    /// palloc tier instead, so exhaustion surfaces as a `QueueError`
    /// rather than unwinding through a half-applied operation.
    pub fn alloc(&self, n: usize, align: usize) -> PAddr {
        match self.try_alloc(n, align) {
            Some(a) => a,
            None => panic!(
                "pmem pool exhausted: need {} words past cursor {}, capacity {} — raise \
                 PmemConfig::capacity_words",
                n,
                self.next_word.load(Ordering::Relaxed),
                self.live.len() * WORDS_PER_LINE
            ),
        }
    }

    /// Bump-allocate `n` words aligned to `align` words, returning `None`
    /// instead of panicking on exhaustion.
    pub fn try_alloc(&self, n: usize, align: usize) -> Option<PAddr> {
        assert!(n > 0);
        let align = align.max(1);
        assert!(align.is_power_of_two(), "alignment must be a power of two");
        loop {
            let cur = self.next_word.load(Ordering::Relaxed);
            let start = (cur + align - 1) & !(align - 1);
            let end = start + n;
            if end > self.live.len() * WORDS_PER_LINE {
                return None;
            }
            if self
                .next_word
                .compare_exchange_weak(cur, end, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return Some(PAddr(start as u32));
            }
        }
    }

    /// Allocate one word.
    pub fn alloc_word(&self) -> PAddr {
        self.alloc(1, 1)
    }

    /// Allocate a 16-byte-aligned pair (for `cas2` cells).
    pub fn alloc_pair(&self) -> PAddr {
        self.alloc(2, 2)
    }

    /// Allocate a whole number of fresh cache lines (line-aligned) — used
    /// for variables that must not share a line with anything else (e.g.
    /// `Head`, `Tail`, per-thread `Head_i` slots).
    pub fn alloc_lines(&self, lines: usize) -> PAddr {
        self.alloc(lines * WORDS_PER_LINE, WORDS_PER_LINE)
    }

    /// Words currently allocated.
    pub fn used_words(&self) -> usize {
        self.next_word.load(Ordering::Relaxed)
    }

    /// Total arena capacity in words.
    pub fn capacity_words(&self) -> usize {
        self.live.len() * WORDS_PER_LINE
    }

    /// Like [`alloc_lines`](Self::alloc_lines), but returns `None` instead
    /// of panicking on exhaustion — for best-effort consumers (the flight
    /// recorder) that must never take down an algorithm's pool.
    pub(crate) fn try_alloc_lines(&self, lines: usize) -> Option<PAddr> {
        let n = lines * WORDS_PER_LINE;
        loop {
            let cur = self.next_word.load(Ordering::Relaxed);
            let start = (cur + WORDS_PER_LINE - 1) & !(WORDS_PER_LINE - 1);
            let end = start + n;
            if end > self.capacity_words() {
                return None;
            }
            if self
                .next_word
                .compare_exchange_weak(cur, end, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return Some(PAddr(start as u32));
            }
        }
    }

    /// This pool's flight-recorder state (see [`crate::obs::flight`]).
    #[inline]
    pub fn flight(&self) -> &crate::obs::flight::FlightRec {
        &self.flight
    }

    /// This pool's size-classed allocator state (knobs + counters; see
    /// [`crate::pmem::palloc`]).
    #[inline]
    pub fn palloc(&self) -> &super::palloc::PallocState {
        &self.palloc
    }

    /// Allocate a `lines`-line recyclable segment through the palloc
    /// tier (magazine → shared freelist → fresh carve). `None` when the
    /// arena is exhausted and nothing suitable is free.
    pub fn palloc_alloc(&self, tid: usize, lines: usize) -> Option<PAddr> {
        super::palloc::alloc(self, tid, lines)
    }

    /// Return a palloc segment (user-area address) for recycling. The
    /// caller must guarantee no thread can still dereference it — see
    /// [`crate::pmem::palloc`]'s module docs for the reuse contract.
    pub fn palloc_free(&self, tid: usize, addr: PAddr) {
        super::palloc::free(self, tid, addr)
    }

    // ------------------------------------------------------------------
    // Crash-step plumbing
    // ------------------------------------------------------------------

    /// Arm the crash countdown: after `steps` further pmem primitives
    /// (across all threads — and across every pool sharing this pool's
    /// topology), the crash flag is raised and every thread unwinds at its
    /// next primitive. This implements the paper's `recovery_steps`
    /// failure framework (§5) at primitive granularity; on a multi-pool
    /// topology the cut lands at one machine-wide point.
    pub fn arm_crash_after(&self, steps: u64) {
        self.shared.arm_crash_after(steps);
    }

    /// Raise the crash flag immediately (topology-wide).
    pub fn crash_now(&self) {
        self.shared.crash_now();
    }

    /// Is the crash flag currently raised?
    pub fn crash_pending(&self) -> bool {
        self.shared.crash_flag.load(Ordering::Relaxed)
    }

    /// The primitive-entry check: countdown + unwind once crashed.
    #[inline]
    fn step(&self, tid: usize) {
        let sh = &*self.shared;
        if sh.stepping.load(Ordering::Relaxed) {
            if sh.steps.fetch_sub(1, Ordering::Relaxed) <= 1 {
                sh.crash_flag.store(true, Ordering::SeqCst);
            }
            if sh.crash_flag.load(Ordering::Relaxed) {
                raise_crash(tid);
            }
        }
    }

    // ------------------------------------------------------------------
    // Virtual-time metering internals
    // ------------------------------------------------------------------

    /// Read thread `tid`'s virtual clock (simulated ns; topology-wide).
    #[inline]
    pub fn vtime(&self, tid: usize) -> u64 {
        self.shared.vtime(tid)
    }

    /// Maximum virtual clock across threads — the simulated makespan.
    pub fn max_vtime(&self) -> u64 {
        self.shared.max_vtime()
    }

    /// Zero all virtual clocks, line stamps, masks and counters (bench
    /// phase boundary). Must not race with workers. Clock reset is
    /// topology-wide (idempotent: a topology resetting every pool clears
    /// the shared clocks more than once, harmlessly).
    pub fn reset_meter(&self) {
        self.shared.reset_vclocks();
        for s in self.stamps.iter() {
            s.store(0, Ordering::Relaxed);
        }
        for m in self.masks.iter() {
            m.store(0, Ordering::Relaxed);
        }
        self.nvm_chain.store(0, Ordering::Relaxed);
        self.stats.reset();
    }

    /// Join the line stamp into the caller's clock, add `cost`, and return
    /// the caller's new clock value.
    #[inline]
    fn join_charge(&self, tid: usize, line: usize, cost: u64) -> u64 {
        let own = self.shared.vclocks[tid].load(Ordering::Relaxed);
        let stamp = self.stamps[line].load(Ordering::Relaxed);
        let t = own.max(stamp) + cost;
        self.shared.vclocks[tid].store(t, Ordering::Relaxed);
        t
    }

    /// Is the calling thread homed on a different socket than this pool?
    /// Cross-socket primitives pay the interconnect penalties
    /// (`CostModel::remote_pwb_ns` / `remote_rmw_ns`). Always false for a
    /// standalone pool (socket 0, all homes 0) — the degenerate case
    /// charges exactly the pre-topology costs.
    #[inline]
    fn cross_socket(&self, tid: usize) -> bool {
        self.shared.home_of(tid) != self.socket
    }

    /// Declare the contention level of all lines covering `words` words
    /// starting at `a`. Data structures call this at construction (see
    /// [`Hotness`]).
    pub fn set_hot(&self, a: PAddr, words: usize, h: Hotness) {
        let first = a.line();
        let last = a.add(words.saturating_sub(1)).line();
        for line in first..=last {
            self.hot[line].store(h as u8, Ordering::Relaxed);
        }
    }

    /// Set the number of active worker threads (harness calls this before
    /// a run; bounds the contention of Global lines).
    pub fn set_active_threads(&self, n: usize) {
        self.active_threads.store(n.max(1) as u32, Ordering::Relaxed);
    }

    /// Effective accessor count of a line: `min(declared, active_threads)`.
    #[inline]
    fn k_of(&self, line: usize) -> u32 {
        let active = self.active_threads.load(Ordering::Relaxed);
        match self.hot[line].load(Ordering::Relaxed) {
            x if x == Hotness::Private as u8 => 1,
            x if x == Hotness::Pairwise as u8 => 2.min(active),
            _ => active,
        }
    }

    /// Is a coherence transfer charged for accessing this line?
    #[inline]
    fn is_remote(&self, _tid: usize, line: usize) -> bool {
        self.k_of(line) > 1
    }

    /// Update the caller's cache entry for `line` to `stamp` (after the
    /// caller itself wrote/flushed it).
    #[inline]
    fn refresh_cache(&self, tid: usize, line: usize, stamp: u64) {
        unsafe {
            let cache = &mut *self.pending[tid].read_cache.get();
            for e in cache.iter_mut() {
                if e.0 == line as u32 {
                    e.1 = stamp;
                    return;
                }
            }
            let cur = &mut *self.pending[tid].read_cursor.get();
            cache[*cur] = (line as u32, stamp);
            *cur = (*cur + 1) % READ_CACHE_WAYS;
        }
    }

    /// Load/store remoteness: shared line AND not in the caller's cache
    /// with an unchanged stamp (spinning on an unchanged line, or writing
    /// a line you already own, is a cache hit).
    #[inline]
    fn load_remote(&self, tid: usize, line: usize) -> bool {
        if self.k_of(line) == 1 {
            return false;
        }
        let stamp = self.stamps[line].load(Ordering::Relaxed);
        let slot = &self.pending[tid];
        unsafe {
            let cache = &mut *slot.read_cache.get();
            for e in cache.iter_mut() {
                if e.0 == line as u32 {
                    let hit = e.1 == stamp;
                    e.1 = stamp;
                    return !hit;
                }
            }
            let cur = &mut *slot.read_cursor.get();
            cache[*cur] = (line as u32, stamp);
            *cur = (*cur + 1) % READ_CACHE_WAYS;
        }
        true
    }

    /// Charge `cost` to the caller without touching any line.
    #[inline]
    fn charge(&self, tid: usize, cost: u64) -> u64 {
        let t = self.shared.vclocks[tid].load(Ordering::Relaxed) + cost;
        self.shared.vclocks[tid].store(t, Ordering::Relaxed);
        t
    }

    /// Publish the caller's clock to the line stamp (release side of the
    /// Lamport construction).
    #[inline]
    fn publish(&self, line: usize, t: u64) {
        self.stamps[line].fetch_max(t, Ordering::Relaxed);
    }

    /// Update the line's accessor mask, returning the number of distinct
    /// recent accessors including the caller. Occasionally decays the mask
    /// so stale accessors age out. (Debug/inspection only — costs come
    /// from declared hotness; see `k_of`.)
    #[allow(dead_code)]
    #[inline]
    fn touch_mask(&self, tid: usize, line: usize) -> u32 {
        let bit = 1u64 << (tid % 64);
        let slot = &self.pending[tid];
        // Cheap thread-local xorshift to decide decay (~1/64 of touches).
        let decay = unsafe {
            let s = &mut *slot.decay_rng.get();
            *s ^= *s << 13;
            *s ^= *s >> 7;
            *s ^= *s << 17;
            (*s & 63) == 0
        };
        if decay {
            self.masks[line].store(bit, Ordering::Relaxed);
            1
        } else {
            let prev = self.masks[line].fetch_or(bit, Ordering::Relaxed);
            (prev | bit).count_ones()
        }
    }

    /// Historical distinct-accessor estimate (statistics/debug only).
    #[allow(dead_code)]
    #[inline]
    fn line_accessors(&self, line: usize) -> u32 {
        self.masks[line].load(Ordering::Relaxed).count_ones().max(1)
    }

    // ------------------------------------------------------------------
    // Word access helpers
    // ------------------------------------------------------------------

    #[inline]
    fn word(&self, a: PAddr) -> &AtomicU64 {
        &self.live[a.line()].0[a.offset_in_line()]
    }

    #[inline]
    fn shadow_word(&self, a: PAddr) -> &AtomicU64 {
        &self.shadow[a.line()].0[a.offset_in_line()]
    }

    // ------------------------------------------------------------------
    // Primitives (paper §2): read/write, FAI, GET&SET, CAS, CAS2, TAS
    // ------------------------------------------------------------------

    /// Atomic 64-bit load.
    #[inline]
    pub fn load(&self, tid: usize, a: PAddr) -> u64 {
        self.step(tid);
        self.stats.of(tid).load();
        let line = a.line();
        let remote = self.load_remote(tid, line);
        let v = self.word(a).load(Ordering::Acquire);
        self.join_charge(tid, line, self.cfg.cost.load_cost(remote));
        v
    }

    /// Atomic 64-bit store (release).
    #[inline]
    pub fn store(&self, tid: usize, a: PAddr, v: u64) {
        self.step(tid);
        self.stats.of(tid).store();
        let line = a.line();
        // A store to a line we still hold (unchanged stamp) is local; a
        // line someone else touched needs an RFO transfer.
        let remote = self.load_remote(tid, line);
        if remote {
            self.stats.of(tid).conflict(1);
        }
        self.word(a).store(v, Ordering::Release);
        let t = self.join_charge(tid, line, self.cfg.cost.store_cost(remote));
        self.publish(line, t);
        self.refresh_cache(tid, line, self.stamps[line].load(Ordering::Relaxed));
    }

    /// Shared RMW bookkeeping: conflict counting, vclock chain.
    /// `remote` must be sampled BEFORE the RMW executes (the RMW itself
    /// advances the stamp).
    ///
    /// RMWs grow the line stamp by **their cost only** (`fetch_add`): the
    /// stamp is the line's cumulative serialization ("handoff") time, so
    /// concurrent RMWs on a hot spot queue behind one another — without
    /// dragging each thread's whole local timeline into the chain. (A
    /// max-join here would let one thread's scheduling quantum serialize
    /// every reader's virtual time on this 1-core testbed — see DESIGN.md
    /// §1.) Stores, by contrast, publish the writer's full clock: they are
    /// the release edges spin-waiters synchronize on (combining handoffs).
    #[inline]
    fn rmw_meter(&self, tid: usize, line: usize, remote: bool) {
        if remote {
            self.stats.of(tid).conflict(1);
        }
        let mut cost = self.cfg.cost.rmw_cost(remote);
        // Cross-socket atomic: directory indirection + interconnect hop
        // (multi-pool topologies only — see `cross_socket`). The penalty
        // joins the line's serialization chain like the base cost: a
        // remote RMW occupies the line for longer.
        if self.cross_socket(tid) {
            cost += self.cfg.cost.remote_rmw_ns;
            self.stats.of(tid).remote_op();
        }
        let chain = self.stamps[line].fetch_add(cost, Ordering::Relaxed) + cost;
        let own = self.shared.vclocks[tid].load(Ordering::Relaxed) + cost;
        self.shared.vclocks[tid].store(own.max(chain), Ordering::Relaxed);
    }

    /// FETCH&INCREMENT — returns the previous value (paper §2a).
    #[inline]
    pub fn fai(&self, tid: usize, a: PAddr) -> u64 {
        self.fetch_add(tid, a, 1)
    }

    /// FETCH&ADD of `k`.
    #[inline]
    pub fn fetch_add(&self, tid: usize, a: PAddr, k: u64) -> u64 {
        self.step(tid);
        self.stats.of(tid).rmw();
        let remote = self.is_remote(tid, a.line());
        let v = self.word(a).fetch_add(k, Ordering::AcqRel);
        self.rmw_meter(tid, a.line(), remote);
        v
    }

    /// GET&SET — store `v`, return previous value (paper §2b).
    #[inline]
    pub fn swap(&self, tid: usize, a: PAddr, v: u64) -> u64 {
        self.step(tid);
        self.stats.of(tid).rmw();
        let remote = self.is_remote(tid, a.line());
        let old = self.word(a).swap(v, Ordering::AcqRel);
        self.rmw_meter(tid, a.line(), remote);
        old
    }

    /// Bitwise OR, returns previous value (used for TEST&SET on flag bits).
    #[inline]
    pub fn fetch_or(&self, tid: usize, a: PAddr, bits: u64) -> u64 {
        self.step(tid);
        self.stats.of(tid).rmw();
        let remote = self.is_remote(tid, a.line());
        let old = self.word(a).fetch_or(bits, Ordering::AcqRel);
        self.rmw_meter(tid, a.line(), remote);
        old
    }

    /// Bitwise AND, returns previous value (used for RESET on flag bits).
    #[inline]
    pub fn fetch_and(&self, tid: usize, a: PAddr, bits: u64) -> u64 {
        self.step(tid);
        self.stats.of(tid).rmw();
        let remote = self.is_remote(tid, a.line());
        let old = self.word(a).fetch_and(bits, Ordering::AcqRel);
        self.rmw_meter(tid, a.line(), remote);
        old
    }

    /// COMPARE&SWAP (paper §2c). Returns `true` on success.
    #[inline]
    pub fn cas(&self, tid: usize, a: PAddr, old: u64, new: u64) -> bool {
        self.step(tid);
        self.stats.of(tid).rmw();
        let remote = self.is_remote(tid, a.line());
        let ok = self
            .word(a)
            .compare_exchange(old, new, Ordering::AcqRel, Ordering::Acquire)
            .is_ok();
        if !ok {
            self.stats.of(tid).cas_failure();
        }
        // A failed CAS still acquired the line exclusively (RFO) — meter it
        // the same way.
        self.rmw_meter(tid, a.line(), remote);
        ok
    }

    /// CAS2 — 128-bit compare-and-swap over the 16-byte-aligned pair at `a`
    /// (paper §2: operates atomically on an array of two elements).
    /// Returns `true` on success.
    #[inline]
    pub fn cas2(&self, tid: usize, a: PAddr, old: (u64, u64), new: (u64, u64)) -> bool {
        debug_assert_eq!(a.word() % 2, 0, "cas2 target must be 16B aligned");
        debug_assert!(a.offset_in_line() + 1 < WORDS_PER_LINE || a.offset_in_line() % 2 == 0);
        self.step(tid);
        self.stats.of(tid).rmw();
        let remote = self.is_remote(tid, a.line());
        let ptr = self.word(a) as *const AtomicU64;
        let (_, _, ok) = unsafe { atomic128::cas128(ptr, old.0, old.1, new.0, new.1) };
        if !ok {
            self.stats.of(tid).cas_failure();
        }
        self.rmw_meter(tid, a.line(), remote);
        ok
    }

    /// Atomic 128-bit load of the pair at `a` (16-byte aligned).
    #[inline]
    pub fn load_pair(&self, tid: usize, a: PAddr) -> (u64, u64) {
        debug_assert_eq!(a.word() % 2, 0);
        self.step(tid);
        self.stats.of(tid).load();
        let line = a.line();
        let remote = self.load_remote(tid, line);
        let ptr = self.word(a) as *const AtomicU64;
        let v = unsafe { atomic128::load128(ptr) };
        self.join_charge(tid, line, self.cfg.cost.load_cost(remote));
        v
    }

    /// TEST&SET on bit `bit` of the word at `a`; returns the bit's previous
    /// value (paper §2).
    #[inline]
    pub fn tas_bit(&self, tid: usize, a: PAddr, bit: u32) -> bool {
        let old = self.fetch_or(tid, a, 1u64 << bit);
        old & (1u64 << bit) != 0
    }

    /// RESET of bit `bit` at `a` (paper §2 companion to TEST&SET).
    #[inline]
    pub fn reset_bit(&self, tid: usize, a: PAddr, bit: u32) {
        let _ = self.fetch_and(tid, a, !(1u64 << bit));
    }

    // ------------------------------------------------------------------
    // Persistence instructions (paper §2)
    // ------------------------------------------------------------------

    /// `pwb` — asynchronously request a write-back of the line containing
    /// `a`. The flush is *queued*; it is realized by the next `psync` (or,
    /// nondeterministically, by crash-time eviction).
    pub fn pwb(&self, tid: usize, a: PAddr) {
        self.step(tid);
        self.stats.of(tid).pwb_at(crate::obs::current_site());
        let line = a.line();
        let k = self.k_of(line);
        let mut cost = self.cfg.cost.pwb_cost(k);
        // Cross-socket flush: the write-back crosses the interconnect to
        // the remote socket's NVM controller (multi-pool topologies only).
        // The penalty rides the line chain like the base flush cost — a
        // remote flush of a hot line delays its contenders for longer,
        // which is exactly the effect `benches/fig8_topology` measures.
        if self.cross_socket(tid) {
            cost += self.cfg.cost.remote_pwb_ns;
            self.stats.of(tid).remote_op();
        }
        // The flush occupies the line: its cost joins the line's
        // serialization chain, so subsequent accessors of a *hot* line
        // queue behind this flush — the effect Figures 2–3 measure. (Same
        // cost-only chain growth as RMWs; see rmw_meter.) Flushes also
        // share this pool's NVM media: every pwb appends to the per-pool
        // bandwidth chain and waits for it.
        let chain = self.stamps[line].fetch_add(cost, Ordering::Relaxed) + cost;
        let media = self.cfg.cost.nvm_flush_ns;
        let nvm = self.nvm_chain.fetch_add(media, Ordering::Relaxed) + media;
        let own = self.shared.vclocks[tid].load(Ordering::Relaxed) + cost;
        self.shared.vclocks[tid].store(own.max(chain).max(nvm), Ordering::Relaxed);
        if self.cfg.cost.meter == MeterMode::WallclockSpin {
            spin_ns(cost);
        }
        // Queue for the next psync (dedupe: pending sets are tiny).
        unsafe {
            let q = &mut *self.pending[tid].lines.get();
            let l32 = line as u32;
            if !q.contains(&l32) {
                q.push(l32);
            }
        }
    }

    /// `pfence` — order preceding `pwb`s before subsequent ones. Flush
    /// queues are per-thread FIFO in this model, so this only charges time
    /// (kept for API fidelity; counted separately).
    pub fn pfence(&self, tid: usize) {
        self.step(tid);
        self.stats.of(tid).pfence();
        self.charge(tid, self.cfg.cost.pfence_ns);
        if self.cfg.cost.meter == MeterMode::WallclockSpin {
            spin_ns(self.cfg.cost.pfence_ns);
        }
    }

    /// `psync` — block until all of this thread's queued `pwb`s are
    /// realized (live → shadow). Counted against the calling thread's
    /// ambient [`crate::obs::ObsSite`] and traced when tracing is armed.
    pub fn psync(&self, tid: usize) {
        self.step(tid);
        let site = crate::obs::current_site();
        self.stats.of(tid).psync_at(site);
        let drained = unsafe {
            let q = &mut *self.pending[tid].lines.get();
            for &line in q.iter() {
                self.flush_line(line as usize);
            }
            let n = q.len();
            q.clear();
            n
        };
        let cost = self.cfg.cost.psync_cost(drained);
        let now = self.charge(tid, cost);
        if self.cfg.cost.meter == MeterMode::WallclockSpin {
            spin_ns(cost);
        }
        crate::obs::trace::psync(tid, now, site, self.socket, drained);
    }

    /// Copy one line live → shadow (the flush taking effect).
    fn flush_line(&self, line: usize) {
        for i in 0..WORDS_PER_LINE {
            let v = self.live[line].0[i].load(Ordering::Acquire);
            self.shadow[line].0[i].store(v, Ordering::Release);
        }
    }

    /// Persist an address range synchronously (helper for recovery code and
    /// structure initialization: pwb every line + one psync).
    pub fn persist_range(&self, tid: usize, a: PAddr, words: usize) {
        let first = a.line();
        let last = a.add(words.saturating_sub(1).max(0)).line();
        for line in first..=last {
            self.pwb(tid, PAddr((line * WORDS_PER_LINE) as u32));
        }
        self.psync(tid);
    }

    // ------------------------------------------------------------------
    // Crash + recovery support
    // ------------------------------------------------------------------

    /// Commit a simulated full-system crash. Call only after all worker
    /// threads have unwound (the harness joins them first).
    ///
    /// 1. Each queued-but-unsynced `pwb` is realized with probability
    ///    `pending_flush_prob` (flush issued, may or may not have landed).
    /// 2. Each *dirty* line (live ≠ shadow) is written back with
    ///    probability `evict_prob` (uncontrolled cache eviction — paper
    ///    footnote 3).
    /// 3. All live state is reset from the shadow: volatile contents lost.
    /// 4. Pending queues, masks and stamps are cleared; the epoch counter
    ///    is bumped; the crash flag and step countdown are disarmed.
    ///
    /// Multi-pool topologies must NOT call this per pool (it would bump
    /// the shared epoch once per pool): use [`crate::pmem::Topology::crash`],
    /// which runs [`PmemPool::crash_storage`] on every pool and finishes
    /// the shared cut once.
    pub fn crash(&self, rng: &mut Xoshiro256) {
        self.crash_storage(rng);
        self.shared.finish_crash();
    }

    /// The storage half of a crash (steps 1–3 above plus per-pool meter
    /// reset), without touching the shared crash machinery.
    pub(crate) fn crash_storage(&self, rng: &mut Xoshiro256) {
        // (1) Pending flushes race the failure.
        for slot in self.pending.iter() {
            unsafe {
                let q = &mut *slot.lines.get();
                for &line in q.iter() {
                    if rng.chance(self.cfg.pending_flush_prob) {
                        self.flush_line(line as usize);
                    }
                }
                q.clear();
            }
        }
        // (2) Background eviction of dirty lines.
        let used_lines = self.used_words().div_ceil(WORDS_PER_LINE).min(self.live.len());
        for line in 0..used_lines {
            if self.cfg.evict_prob > 0.0 && self.line_dirty(line) {
                if rng.chance(self.cfg.evict_prob) {
                    self.flush_line(line);
                }
            }
        }
        // (3) Volatile state dies: live := shadow.
        for line in 0..used_lines {
            for i in 0..WORDS_PER_LINE {
                let v = self.shadow[line].0[i].load(Ordering::Acquire);
                self.live[line].0[i].store(v, Ordering::Release);
            }
        }
        // (4) Reset this pool's metering state (the shared crash
        // machinery is finished by the caller — once per cut).
        for s in self.stamps.iter() {
            s.store(0, Ordering::Relaxed);
        }
        for m in self.masks.iter() {
            m.store(0, Ordering::Relaxed);
        }
        self.nvm_chain.store(0, Ordering::Relaxed);
        // (5) Rebuild the allocator's volatile freelists from the durable
        // segment headers (live == shadow here; unmetered one-scan walk).
        super::palloc::rebuild(self);
    }

    /// Is the line containing any of the range dirty (live ≠ shadow)?
    fn line_dirty(&self, line: usize) -> bool {
        for i in 0..WORDS_PER_LINE {
            if self.live[line].0[i].load(Ordering::Acquire)
                != self.shadow[line].0[i].load(Ordering::Acquire)
            {
                return true;
            }
        }
        false
    }

    /// Test/verifier helper: read the *shadow* (NVM) value directly.
    pub fn read_shadow(&self, a: PAddr) -> u64 {
        self.shadow_word(a).load(Ordering::Acquire)
    }

    /// Test helper: is the word's live value unflushed?
    pub fn is_dirty(&self, a: PAddr) -> bool {
        self.word(a).load(Ordering::Acquire) != self.shadow_word(a).load(Ordering::Acquire)
    }

    /// Non-metered, non-crashing raw load — for assertions in tests and for
    /// the verifier's post-mortem inspection. Never use on algorithm paths.
    pub fn peek(&self, a: PAddr) -> u64 {
        self.word(a).load(Ordering::Acquire)
    }

    /// Non-metered raw store — test setup only.
    pub fn poke(&self, a: PAddr, v: u64) {
        self.word(a).store(v, Ordering::Release);
    }

    /// Non-metered raw store to live **and** shadow — "freshly formatted
    /// NVM" initialization. Reserved for flight-recorder metadata
    /// (directory/ring headers), which must be discoverable after a crash
    /// without charging metered construction traffic that would shift
    /// step-swept crash cuts. Never use on algorithm state: it bypasses
    /// the persistency model entirely.
    pub(crate) fn poke_durable(&self, a: PAddr, v: u64) {
        self.word(a).store(v, Ordering::Release);
        self.shadow_word(a).store(v, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::crash::{install_quiet_crash_hook, run_guarded};
    use crate::pmem::latency::CostModel;

    fn pool() -> PmemPool {
        PmemPool::new(PmemConfig {
            capacity_words: 1 << 12,
            cost: CostModel::default(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 1,
        })
    }

    #[test]
    fn alloc_alignment_and_reservation() {
        let p = pool();
        let a = p.alloc_word();
        assert!(!a.is_null(), "word 0 must be reserved");
        let pair = p.alloc_pair();
        assert_eq!(pair.word() % 2, 0);
        let line = p.alloc_lines(1);
        assert_eq!(line.word() % WORDS_PER_LINE, 0);
        assert_eq!(line.offset_in_line(), 0);
    }

    #[test]
    #[should_panic(expected = "exhausted")]
    fn alloc_exhaustion_panics() {
        let p = pool();
        let _ = p.alloc(1 << 13, 1);
    }

    #[test]
    fn store_load_roundtrip() {
        let p = pool();
        let a = p.alloc_word();
        p.store(0, a, 0xDEAD);
        assert_eq!(p.load(0, a), 0xDEAD);
    }

    #[test]
    fn rmw_semantics() {
        let p = pool();
        let a = p.alloc_word();
        assert_eq!(p.fai(0, a), 0);
        assert_eq!(p.fai(0, a), 1);
        assert_eq!(p.swap(0, a, 100), 2);
        assert!(p.cas(0, a, 100, 200));
        assert!(!p.cas(0, a, 100, 300));
        assert_eq!(p.load(0, a), 200);
        assert_eq!(p.fetch_add(0, a, 5), 200);
        assert_eq!(p.load(0, a), 205);
    }

    #[test]
    fn tas_and_reset() {
        let p = pool();
        let a = p.alloc_word();
        assert!(!p.tas_bit(0, a, 63));
        assert!(p.tas_bit(0, a, 63));
        p.reset_bit(0, a, 63);
        assert!(!p.tas_bit(0, a, 63));
    }

    #[test]
    fn cas2_through_pool() {
        let p = pool();
        let a = p.alloc_pair();
        p.store(0, a, 1);
        p.store(0, a.add(1), 2);
        assert!(p.cas2(0, a, (1, 2), (10, 20)));
        assert_eq!(p.load_pair(0, a), (10, 20));
        assert!(!p.cas2(0, a, (1, 2), (0, 0)));
        assert_eq!(p.load_pair(0, a), (10, 20));
    }

    #[test]
    fn unpersisted_write_lost_at_crash() {
        let p = pool();
        let a = p.alloc_word();
        p.store(0, a, 42);
        assert!(p.is_dirty(a));
        let mut rng = Xoshiro256::seed_from(7);
        p.crash(&mut rng);
        assert_eq!(p.load(0, a), 0, "un-pwb'd write must be lost (evict_prob=0)");
        assert_eq!(p.epoch(), 1);
    }

    #[test]
    fn pwb_alone_is_not_durable_without_psync() {
        // pending_flush_prob = 0: a queued-but-unsynced pwb never lands.
        let p = pool();
        let a = p.alloc_word();
        p.store(0, a, 42);
        p.pwb(0, a);
        let mut rng = Xoshiro256::seed_from(7);
        p.crash(&mut rng);
        assert_eq!(p.load(0, a), 0, "pwb without psync must not guarantee durability");
    }

    #[test]
    fn pwb_psync_is_durable() {
        let p = pool();
        let a = p.alloc_word();
        p.store(0, a, 42);
        p.pwb(0, a);
        p.psync(0);
        assert!(!p.is_dirty(a));
        let mut rng = Xoshiro256::seed_from(7);
        p.crash(&mut rng);
        assert_eq!(p.load(0, a), 42);
    }

    #[test]
    fn pending_flush_probability_one_always_lands() {
        let p = PmemPool::new(PmemConfig {
            capacity_words: 1 << 12,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 1.0,
            seed: 1,
        });
        let a = p.alloc_word();
        p.store(0, a, 7);
        p.pwb(0, a);
        let mut rng = Xoshiro256::seed_from(3);
        p.crash(&mut rng);
        assert_eq!(p.load(0, a), 7, "pending pwb with prob 1.0 must land at crash");
    }

    #[test]
    fn eviction_probability_one_persists_dirty_lines() {
        let p = PmemPool::new(PmemConfig {
            capacity_words: 1 << 12,
            cost: CostModel::zero(),
            evict_prob: 1.0,
            pending_flush_prob: 0.0,
            seed: 1,
        });
        let a = p.alloc_word();
        p.store(0, a, 9); // never pwb'd
        let mut rng = Xoshiro256::seed_from(3);
        p.crash(&mut rng);
        assert_eq!(p.load(0, a), 9, "evict_prob=1.0 must write back dirty lines");
    }

    #[test]
    fn flush_is_line_granular() {
        let p = pool();
        let base = p.alloc_lines(1);
        p.store(0, base, 1);
        p.store(0, base.add(7), 7); // same line, different word
        p.pwb(0, base); // flushing any word flushes the whole line
        p.psync(0);
        assert_eq!(p.read_shadow(base), 1);
        assert_eq!(p.read_shadow(base.add(7)), 7);
    }

    #[test]
    fn crash_step_countdown_unwinds() {
        install_quiet_crash_hook();
        let p = pool();
        let a = p.alloc_word();
        p.arm_crash_after(5);
        let out = run_guarded(|| {
            for i in 0..100u64 {
                p.store(0, a, i);
            }
        });
        assert!(out.crashed(), "must crash before 100 stores");
        // The pool unblocks after crash().
        let mut rng = Xoshiro256::seed_from(1);
        p.crash(&mut rng);
        p.store(0, a, 1);
        assert_eq!(p.load(0, a), 1);
    }

    #[test]
    fn vclock_charges_costs() {
        let p = pool();
        let a = p.alloc_word();
        p.set_hot(a, 1, crate::pmem::Hotness::Private);
        let c = p.config().cost.clone();
        assert_eq!(p.vtime(0), 0);
        p.store(0, a, 1);
        assert_eq!(p.vtime(0), c.store_ns);
        let _ = p.load(0, a);
        assert_eq!(p.vtime(0), c.store_ns + c.load_ns);
        let _ = p.fai(0, a);
        assert_eq!(p.vtime(0), c.store_ns + c.load_ns + c.rmw_cost(false));
    }

    #[test]
    fn hotness_drives_costs() {
        let p = pool();
        p.set_active_threads(8);
        let priv_ = p.alloc_lines(1);
        let glob = p.alloc_lines(1);
        p.set_hot(priv_, crate::pmem::WORDS_PER_LINE, crate::pmem::Hotness::Private);
        p.set_hot(glob, crate::pmem::WORDS_PER_LINE, crate::pmem::Hotness::Global);
        let c = p.config().cost.clone();
        let _ = p.fai(0, priv_);
        assert_eq!(p.vtime(0), c.rmw_cost(false));
        let _ = p.fai(1, glob);
        assert_eq!(p.vtime(1), c.rmw_cost(true));
        // Global pwb pays the hot premium; private pwb does not.
        let t1 = p.vtime(1);
        p.pwb(1, glob);
        assert!(p.vtime(1) - t1 >= c.pwb_cost(8));
        let t0 = p.vtime(0);
        p.pwb(0, priv_);
        assert!(p.vtime(0) - t0 >= c.pwb_cost(1));
        // With 1 active thread, Global is uncontended.
        p.set_active_threads(1);
        p.reset_meter();
        let _ = p.fai(0, glob);
        assert_eq!(p.vtime(0), c.rmw_cost(false));
    }

    #[test]
    fn vclock_propagates_through_contended_line() {
        // Thread 0 does expensive work then writes the line; thread 1's
        // subsequent read must inherit thread 0's clock.
        let p = pool();
        let a = p.alloc_word();
        for _ in 0..100 {
            let _ = p.fai(0, a);
        }
        let t0 = p.vtime(0);
        assert!(t0 > 0);
        let _ = p.load(1, a);
        assert!(
            p.vtime(1) >= t0,
            "reader clock {} must catch up to writer clock {}",
            p.vtime(1),
            t0
        );
    }

    #[test]
    fn pwb_on_hot_line_serializes_contenders() {
        // A pwb on a line recently accessed by many threads charges the
        // hot-line premium AND lands on the line stamp.
        let p = pool();
        let a = p.alloc_word();
        for tid in 0..8 {
            let _ = p.fai(tid, a);
        }
        let before = p.vtime(0);
        p.pwb(0, a);
        let cost = p.vtime(0) - before.max(p.vtime(7).min(p.vtime(0)));
        // Cost must exceed the cold pwb cost (8 accessors recorded, modulo
        // probabilistic decay which can only lower k to >= 1).
        assert!(cost >= p.config().cost.pwb_ns);
        // Another thread touching the line inherits the flush time.
        let t_flush = p.vtime(0);
        let _ = p.load(3, a);
        assert!(p.vtime(3) >= t_flush);
    }

    #[test]
    fn swsr_pwb_does_not_affect_other_threads() {
        let p = pool();
        let a = p.alloc_lines(1); // exclusive line
        let b = p.alloc_lines(1);
        p.set_hot(a, crate::pmem::WORDS_PER_LINE, crate::pmem::Hotness::Private);
        p.set_hot(b, crate::pmem::WORDS_PER_LINE, crate::pmem::Hotness::Private);
        p.store(0, a, 1);
        p.pwb(0, a);
        p.psync(0);
        // Thread 1 working on an unrelated line is not delayed.
        let _ = p.fai(1, b);
        assert!(p.vtime(1) <= p.config().cost.rmw_cost(false));
    }

    #[test]
    fn reset_meter_zeroes_everything() {
        let p = pool();
        let a = p.alloc_word();
        let _ = p.fai(0, a);
        p.pwb(0, a);
        p.psync(0);
        p.reset_meter();
        assert_eq!(p.vtime(0), 0);
        assert_eq!(p.max_vtime(), 0);
        assert_eq!(p.stats.total().pwbs, 0);
    }

    #[test]
    fn counters_track_ops() {
        let p = pool();
        let a = p.alloc_word();
        let _ = p.load(2, a);
        p.store(2, a, 1);
        let _ = p.fai(2, a);
        let _ = p.cas(2, a, 999, 0); // fails
        p.pwb(2, a);
        p.pfence(2);
        p.psync(2);
        let t = p.stats.total();
        assert_eq!(t.loads, 1);
        assert_eq!(t.stores, 1);
        assert_eq!(t.rmws, 2);
        assert_eq!(t.cas_failures, 1);
        assert_eq!(t.pwbs, 1);
        assert_eq!(t.pfences, 1);
        assert_eq!(t.psyncs, 1);
    }

    #[test]
    fn persist_range_covers_all_lines() {
        let p = pool();
        let a = p.alloc_lines(3);
        let words = 3 * WORDS_PER_LINE;
        for i in 0..words {
            p.store(0, a.add(i), i as u64 + 1);
        }
        p.persist_range(0, a, words);
        for i in 0..words {
            assert_eq!(p.read_shadow(a.add(i)), i as u64 + 1);
        }
    }

    #[test]
    fn cross_socket_penalties_charged_only_for_remote_homes() {
        // A pool on socket 1 sharing state with homes defaulting to
        // socket 0: thread 0 is remote, a thread re-homed to socket 1 is
        // local and pays exactly the old costs.
        let shared = Arc::new(SharedState::new());
        let p1 = PmemPool::with_shared(
            PmemConfig {
                capacity_words: 1 << 12,
                cost: CostModel::default(),
                evict_prob: 0.0,
                pending_flush_prob: 0.0,
                seed: 1,
            },
            1,
            Arc::clone(&shared),
        );
        let a = p1.alloc_word();
        p1.set_hot(a, 1, Hotness::Private);
        let c = p1.config().cost.clone();
        let _ = p1.fai(0, a);
        assert_eq!(p1.vtime(0), c.rmw_cost(false) + c.remote_rmw_ns);
        let before = p1.vtime(0);
        p1.pwb(0, a);
        assert_eq!(p1.vtime(0) - before, c.pwb_cost(1) + c.remote_pwb_ns);
        assert_eq!(p1.stats.total().remote_ops, 2);
        // Thread 2 homed on this pool's socket: no penalty.
        shared.set_home(2, 1);
        p1.reset_meter();
        let _ = p1.fai(2, a);
        assert_eq!(p1.vtime(2), c.rmw_cost(false));
        assert_eq!(p1.stats.total().remote_ops, 0, "meter reset + local access");
    }

    #[test]
    fn standalone_pool_never_pays_cross_socket() {
        let p = pool(); // socket 0, homes all 0
        let a = p.alloc_word();
        for t in 0..8 {
            let _ = p.fai(t, a);
            p.pwb(t, a);
        }
        assert_eq!(p.stats.total().remote_ops, 0);
    }

    #[test]
    fn concurrent_fai_is_linearizable_count() {
        let p = std::sync::Arc::new(pool());
        let a = p.alloc_word();
        let mut hs = Vec::new();
        for tid in 0..4 {
            let p = std::sync::Arc::clone(&p);
            hs.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    let _ = p.fai(tid, a);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(p.load(0, a), 4000);
    }
}
