//! Crash signalling: how simulated full-system failures interrupt worker
//! threads *in the middle of an operation*.
//!
//! Every pmem primitive polls the pool's crash flag; once set, the primitive
//! unwinds the calling thread with a [`CrashSignal`] panic payload. Worker
//! loops run their workload inside [`run_guarded`], which converts that
//! unwind into [`RunOutcome::Crashed`]. Because the check sits inside the
//! primitives themselves, threads stop at *arbitrary points within* enqueue/
//! dequeue — between a successful `CAS` and its `pwb`, between `TAS(Tail.cb)`
//! and persisting the closed bit, etc. — exactly the windows the paper's
//! durable-linearizability proofs reason about (§4, Scenarios 1–3).

/// Panic payload identifying a simulated crash (not a real bug).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CrashSignal {
    /// Thread that observed the crash flag.
    pub tid: usize,
}

/// Result of running a guarded workload closure.
#[derive(Debug)]
pub enum RunOutcome<T> {
    /// The closure finished normally.
    Completed(T),
    /// The closure was interrupted by a simulated crash.
    Crashed { tid: usize },
}

impl<T> RunOutcome<T> {
    pub fn crashed(&self) -> bool {
        matches!(self, RunOutcome::Crashed { .. })
    }

    pub fn unwrap_completed(self) -> T {
        match self {
            RunOutcome::Completed(t) => t,
            RunOutcome::Crashed { tid } => {
                panic!("expected completion but thread {tid} crashed")
            }
        }
    }
}

/// Run `f`, converting a [`CrashSignal`] unwind into
/// [`RunOutcome::Crashed`]. Real panics (bugs) are resumed.
///
/// The closure is wrapped in `AssertUnwindSafe`: a simulated crash leaves
/// the pool's live state arbitrary by design, and the subsequent
/// [`super::PmemPool::crash`] call normalizes it (live := shadow), so the
/// usual unwind-safety concern (observing broken invariants) does not apply.
pub fn run_guarded<T>(f: impl FnOnce() -> T) -> RunOutcome<T> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(t) => RunOutcome::Completed(t),
        Err(payload) => {
            if let Some(sig) = payload.downcast_ref::<CrashSignal>() {
                RunOutcome::Crashed { tid: sig.tid }
            } else {
                // Not a simulated crash: propagate the real panic.
                std::panic::resume_unwind(payload);
            }
        }
    }
}

/// Install a panic hook that silences [`CrashSignal`] unwinds (they are
/// expected control flow during crash cycles) while keeping default
/// reporting for real panics. Call once from harness/bench entry points.
pub fn install_quiet_crash_hook() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<CrashSignal>().is_some() {
                return; // expected simulated crash — stay quiet
            }
            default(info);
        }));
    });
}

/// Unwind the current thread with a crash signal. Called by pool primitives.
#[cold]
#[inline(never)]
pub(crate) fn raise_crash(tid: usize) -> ! {
    std::panic::panic_any(CrashSignal { tid })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guarded_completion() {
        let r = run_guarded(|| 42);
        assert!(!r.crashed());
        assert_eq!(r.unwrap_completed(), 42);
    }

    #[test]
    fn guarded_crash() {
        install_quiet_crash_hook();
        let r = run_guarded(|| -> u32 { raise_crash(3) });
        match r {
            RunOutcome::Crashed { tid } => assert_eq!(tid, 3),
            _ => panic!("expected crash"),
        }
    }

    #[test]
    fn real_panics_propagate() {
        install_quiet_crash_hook();
        let res = std::panic::catch_unwind(|| {
            let _ = run_guarded(|| panic!("real bug"));
        });
        assert!(res.is_err(), "non-crash panics must not be swallowed");
    }

    #[test]
    #[should_panic(expected = "crashed")]
    fn unwrap_completed_panics_on_crash() {
        install_quiet_crash_hook();
        let r = run_guarded(|| -> u32 { raise_crash(1) });
        let _ = r.unwrap_completed();
    }
}
