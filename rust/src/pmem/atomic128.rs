//! 128-bit compare-and-swap (`CAS2` in the paper, §2).
//!
//! CRQ/PerCRQ cells are 16-byte triplets `(safe bit, index, value)` packed
//! into two adjacent 64-bit words; dequeue/enqueue transitions replace both
//! words atomically (Algorithm 3, lines 14/34/38/41). x86-64 provides
//! `lock cmpxchg16b`; Rust's `std` has no `AtomicU128`, so we emit the
//! instruction with inline asm. `rbx` is reserved by LLVM, hence the
//! save/exchange dance. On non-x86-64 targets a seqlock-striped fallback is
//! compiled instead (correct, slower — documented in DESIGN.md §6).

use std::sync::atomic::AtomicU64;

/// Atomically compare-and-swap the 16-byte pair at `dst` (which MUST be
/// 16-byte aligned and point at two consecutive `AtomicU64`s).
///
/// Returns `(observed_lo, observed_hi, success)`.
///
/// # Safety
/// `dst` must be valid, 16-byte aligned, and only ever accessed through
/// atomic operations (as the pool's `CacheLine` storage guarantees).
#[cfg(target_arch = "x86_64")]
pub unsafe fn cas128(
    dst: *const AtomicU64,
    old_lo: u64,
    old_hi: u64,
    new_lo: u64,
    new_hi: u64,
) -> (u64, u64, bool) {
    debug_assert_eq!(dst as usize % 16, 0, "cas128 target must be 16B aligned");
    let mut out_lo = old_lo;
    let mut out_hi = old_hi;
    let ok: u8;
    // Every operand is pinned to an explicit register: the generic `reg`
    // class may hand out rbx, which we must borrow for cmpxchg16b's B
    // operand (it cannot be named as an asm operand — LLVM reserves it —
    // hence the xchg save/restore through rsi).
    std::arch::asm!(
        "xchg rbx, rsi",
        "lock cmpxchg16b [rdi]",
        "mov rbx, rsi",
        "setz r8b",
        in("rdi") dst,
        inout("rsi") new_lo => _,
        in("rcx") new_hi,
        inout("rax") out_lo,
        inout("rdx") out_hi,
        out("r8b") ok,
        options(nostack),
    );
    (out_lo, out_hi, ok != 0)
}

/// Atomically read the 16-byte pair at `dst` (via a cmpxchg16b with
/// impossible-to-match... actually with whatever is read back: a failed
/// `lock cmpxchg16b` writes the current value into rdx:rax, giving an
/// atomic 128-bit load).
#[cfg(target_arch = "x86_64")]
pub unsafe fn load128(dst: *const AtomicU64) -> (u64, u64) {
    // cmpxchg16b with expected == desired == 0: if the slot IS zero it
    // "succeeds" by writing zero (no visible change); otherwise it fails and
    // returns the current contents. Either way we get an atomic snapshot.
    let (lo, hi, _) = cas128(dst, 0, 0, 0, 0);
    (lo, hi)
}

#[cfg(not(target_arch = "x86_64"))]
mod fallback {
    //! Seqlock-striped fallback for non-x86-64 hosts.
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Mutex;

    const STRIPES: usize = 64;
    static LOCKS: [Mutex<()>; STRIPES] = [const { Mutex::new(()) }; STRIPES];

    fn stripe(dst: *const AtomicU64) -> &'static Mutex<()> {
        &LOCKS[(dst as usize >> 4) % STRIPES]
    }

    pub unsafe fn cas128(
        dst: *const AtomicU64,
        old_lo: u64,
        old_hi: u64,
        new_lo: u64,
        new_hi: u64,
    ) -> (u64, u64, bool) {
        let _g = stripe(dst).lock().unwrap();
        let lo = &*dst;
        let hi = &*dst.add(1);
        let cl = lo.load(Ordering::SeqCst);
        let ch = hi.load(Ordering::SeqCst);
        if cl == old_lo && ch == old_hi {
            lo.store(new_lo, Ordering::SeqCst);
            hi.store(new_hi, Ordering::SeqCst);
            (cl, ch, true)
        } else {
            (cl, ch, false)
        }
    }

    pub unsafe fn load128(dst: *const AtomicU64) -> (u64, u64) {
        let _g = stripe(dst).lock().unwrap();
        ((*dst).load(Ordering::SeqCst), (*dst.add(1)).load(Ordering::SeqCst))
    }
}

#[cfg(not(target_arch = "x86_64"))]
pub use fallback::{cas128, load128};

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[repr(align(16))]
    struct Pair([AtomicU64; 2]);

    #[test]
    fn cas_success_and_failure() {
        let p = Pair([AtomicU64::new(1), AtomicU64::new(2)]);
        let d = p.0.as_ptr();
        unsafe {
            let (lo, hi, ok) = cas128(d, 1, 2, 10, 20);
            assert!(ok);
            assert_eq!((lo, hi), (1, 2));
            assert_eq!(p.0[0].load(Ordering::SeqCst), 10);
            assert_eq!(p.0[1].load(Ordering::SeqCst), 20);

            // Mismatch: no change, observed values returned.
            let (lo, hi, ok) = cas128(d, 1, 2, 99, 99);
            assert!(!ok);
            assert_eq!((lo, hi), (10, 20));
            assert_eq!(p.0[0].load(Ordering::SeqCst), 10);
        }
    }

    #[test]
    fn cas_half_match_fails() {
        let p = Pair([AtomicU64::new(5), AtomicU64::new(6)]);
        unsafe {
            // lo matches, hi doesn't.
            let (_, _, ok) = cas128(p.0.as_ptr(), 5, 0, 1, 1);
            assert!(!ok);
            assert_eq!(p.0[0].load(Ordering::SeqCst), 5);
            assert_eq!(p.0[1].load(Ordering::SeqCst), 6);
        }
    }

    #[test]
    fn atomic_load() {
        let p = Pair([AtomicU64::new(0xAAAA), AtomicU64::new(0xBBBB)]);
        unsafe {
            assert_eq!(load128(p.0.as_ptr()), (0xAAAA, 0xBBBB));
        }
        let z = Pair([AtomicU64::new(0), AtomicU64::new(0)]);
        unsafe {
            assert_eq!(load128(z.0.as_ptr()), (0, 0));
        }
    }

    #[test]
    fn concurrent_cas_is_atomic() {
        // Two threads CAS-increment both halves in lockstep; the pair must
        // never tear (lo != hi would indicate a torn update).
        use std::sync::Arc;
        let p = Arc::new(Pair([AtomicU64::new(0), AtomicU64::new(0)]));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let p = Arc::clone(&p);
            handles.push(std::thread::spawn(move || {
                let d = p.0.as_ptr();
                for _ in 0..10_000 {
                    loop {
                        let (lo, hi) = unsafe { load128(d) };
                        assert_eq!(lo, hi, "torn pair observed");
                        let (_, _, ok) = unsafe { cas128(d, lo, hi, lo + 1, hi + 1) };
                        if ok {
                            break;
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let (lo, hi) = unsafe { load128(p.0.as_ptr()) };
        assert_eq!(lo, 20_000);
        assert_eq!(hi, 20_000);
    }
}
