//! Simulated persistent-memory (NVM) substrate.
//!
//! Implements the paper's *explicit epoch persistency* model (§2) on DRAM:
//!
//! * A [`PmemPool`] is an arena of 64-bit words grouped into 64-byte lines.
//!   Every line has a **live** copy (what concurrent threads read/write — the
//!   "cache/DRAM" view) and a **shadow** copy (the NVM view — what survives a
//!   crash).
//! * [`PmemPool::pwb`] *requests* a write-back of a line (asynchronous: the
//!   flush is queued per-thread); [`PmemPool::pfence`] orders queued flushes;
//!   [`PmemPool::psync`] blocks until the calling thread's queued flushes are
//!   realized (live → shadow).
//! * [`PmemPool::crash`] simulates a full-system crash failure: worker
//!   threads unwind mid-operation (see [`crash`]), each still-pending or
//!   dirty line is written back with a configurable probability (modelling
//!   uncontrolled cache eviction — the paper's footnote 3), and then all
//!   live state is reset from the shadow (volatile contents are lost).
//!
//! ## Multi-pool topology
//!
//! A [`Topology`] groups several independent pools ("sockets"): each has
//! its own arena, per-socket NVM bandwidth chain, stats and crash-time
//! nondeterminism, while the per-thread virtual clocks and the crash cut
//! are shared machine-wide. Every thread id has a **home socket**
//! (round-robin, the paper's §5 pinning order); `pwb`s and RMWs issued
//! against a pool on a different socket charge the cost model's
//! cross-socket penalties ([`CostModel::remote_pwb_ns`] /
//! [`CostModel::remote_rmw_ns`]). [`Topology::single`] is the degenerate
//! one-pool case and charges exactly the pre-topology costs; multi-pool
//! structures address memory through pool-qualified [`GAddr`]s.
//!
//! [`CostModel::remote_pwb_ns`]: latency::CostModel::remote_pwb_ns
//! [`CostModel::remote_rmw_ns`]: latency::CostModel::remote_rmw_ns
//!
//! ## Allocation
//!
//! The base allocator is a bump cursor ([`PmemPool::alloc`] /
//! [`PmemPool::try_alloc`]); the [`palloc`] module layers a size-classed
//! recycling tier on top of it — per-thread magazines over per-class
//! shared freelists, one-line crash-consistent segment headers whose
//! durability piggybacks on caller-issued psyncs, and a conservative
//! post-crash rebuild scan — so retired queue structures (closed LCRQ
//! rings, retired shard stripes, drained blockfifo blocks) are recycled
//! instead of leaked.
//!
//! ## Virtual-time metering
//!
//! The testbed has one physical core, so wall-clock cannot reproduce the
//! paper's scaling curves. Instead every primitive charges a calibrated cost
//! (see [`latency::CostModel`]) to the calling thread's **virtual clock**,
//! and every line carries a **stamp** — the virtual time of its last
//! writer/flusher. RMWs and loads join (`max`) the line stamp into the
//! caller's clock; RMWs, stores and flushes publish the caller's clock back
//! to the stamp. This is a Lamport-clock construction: serialization on a
//! contended line (e.g. `FAI(Head)`) shows up as a serial chain of stamps,
//! so *simulated throughput = ops / max-thread-virtual-time* exhibits
//! exactly the contention behaviour the paper measures (a `pwb` on a hot
//! line inserts its latency into every contender's critical path; a `pwb`
//! on a single-writer line costs only its owner).

pub mod atomic128;
pub mod crash;
pub mod latency;
pub mod layout;
pub mod palloc;
pub mod pool;
pub mod stats;
pub mod topology;

pub use crash::{run_guarded, CrashSignal, RunOutcome};
pub use latency::{CostModel, MeterMode};
pub use layout::{PAddr, WORDS_PER_LINE};
pub use palloc::PallocState;
pub use pool::{Hotness, PmemPool, MAX_THREADS};
pub use stats::{OpCounters, PoolStats};
pub use topology::{GAddr, PlacementPolicy, Topology, MAX_POOLS};

/// Pool-wide configuration.
#[derive(Clone, Debug)]
pub struct PmemConfig {
    /// Arena capacity in 64-bit words (live + shadow each this size).
    pub capacity_words: usize,
    /// Cost model for virtual-time metering.
    pub cost: CostModel,
    /// Probability that a *dirty, un-flushed* line is nonetheless written
    /// back at crash time (uncontrolled cache eviction).
    pub evict_prob: f64,
    /// Probability that a line whose `pwb` was issued but not yet `psync`ed
    /// is realized at crash time.
    pub pending_flush_prob: f64,
    /// RNG seed for crash nondeterminism (the harness typically re-seeds per
    /// cycle).
    pub seed: u64,
}

impl Default for PmemConfig {
    fn default() -> Self {
        Self {
            capacity_words: 1 << 20, // 8 MiB live + 8 MiB shadow
            cost: CostModel::default(),
            evict_prob: 0.25,
            pending_flush_prob: 0.5,
            seed: 0x5EED_CAFE,
        }
    }
}

impl PmemConfig {
    /// Convenience: set capacity (in words).
    pub fn with_capacity(mut self, words: usize) -> Self {
        self.capacity_words = words;
        self
    }

    /// Convenience: set the cost model.
    pub fn with_cost(mut self, cost: CostModel) -> Self {
        self.cost = cost;
        self
    }

    /// Convenience: set the seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}
