//! `palloc` — a size-classed persistent allocator layered on the bump
//! arena, with per-thread magazines and crash-consistent segment
//! metadata.
//!
//! ## Why
//!
//! [`PmemPool::alloc`] is a bump-only cursor: every structure the queues
//! retire (closed LCRQ rings, retired shard-plan stripes, drained
//! blockfifo blocks) leaks by design, and the cursor itself is one
//! contended CAS word on the segment-allocation path. `palloc` adds a
//! recycling tier **on top of** the bump arena — fresh memory still
//! comes from the cursor, but freed segments re-enter circulation — with
//! three properties the queues need:
//!
//! 1. **No shared word on the steady-state path.** Each thread owns a
//!    per-size-class *magazine* (a small cache of free segments). A
//!    magazine hit touches only thread-local state plus the segment's
//!    own header line; misses refill from a per-class shared freelist
//!    under a short volatile mutex.
//! 2. **Crash-consistent metadata at zero extra psyncs.** Every segment
//!    is prefixed by a one-line header whose single state word says
//!    `LIVE` or `FREE`. State flips are a store + `pwb` attributed to
//!    [`ObsSite::Alloc`](crate::obs::ObsSite) — durability piggybacks on
//!    the **caller's** next `psync` (exactly like the flight recorder's
//!    `presync`), so the paper's steady-state psync budgets (1/B + 1/K,
//!    ~1/block) are untouched and `tests/obs_ledger.rs` can assert zero
//!    psyncs at the `Alloc` site.
//! 3. **Conservative recovery.** A persistent *extent directory* (carved
//!    at pool construction, like the flight-recorder directory) records
//!    every segment ever carved. Post-crash rebuild is one scan: a
//!    segment whose header is durably `FREE` re-enters the freelists;
//!    anything else — including segments whose free `pwb` had not
//!    reached a psync — is treated as live (leaked-until-audit). The
//!    scan can lose a *non-durable* free, never a durable one, and can
//!    never hand out a segment that might still be reachable.
//!
//! ## Crash-safety argument
//!
//! The invariant is **durably-reachable ⇒ durably-LIVE**. A fresh carve
//! formats its header `LIVE` with a durable write before the caller ever
//! sees the address. A recycled segment's `LIVE` flip is a store + `pwb`
//! queued on the caller's thread *before* the caller can publish a
//! pointer to it; any psync that makes the pointer durable drains the
//! header flush first. Conversely a free's `FREE` flip becomes durable
//! at the freeing thread's next psync; until then recovery sees `LIVE`
//! and conservatively leaks the segment. Since recovery only reuses
//! durably-`FREE` segments, and a durably-`FREE` segment cannot be
//! durably reachable (the header line is flushed by the same psync
//! discipline that would have flushed the pointer), no crash point can
//! cause a double allocation.
//!
//! **Reuse safety against concurrent readers is the caller's job**: a
//! queue must not `palloc_free` a segment until no thread can still
//! dereference it (the LCRQ gates node frees on an epoch grace period
//! *and* on the durable head pointer having moved past the node; see
//! `queues/lcrq.rs`). `palloc` itself only guarantees alloc/free/crash
//! atomicity of its own metadata.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

use crossbeam_utils::CachePadded;

use super::layout::{PAddr, WORDS_PER_LINE};
use super::pool::{PmemPool, MAX_THREADS};
use crate::obs::{self, ObsSite};

/// Distinct segment sizes (size classes) per pool. Classes bind lazily
/// to the exact line counts requested — the queues allocate a handful of
/// fixed shapes (ring nodes, endpoint lines, block strides), so exact
/// binding gives zero internal fragmentation. Requests beyond the table
/// still allocate (bump fallback) but their frees are counted leaked.
pub const MAX_CLASSES: usize = 8;

/// Default per-thread magazine capacity (segments per class).
pub const DEFAULT_MAGAZINE: usize = 8;

/// Extent-directory capacity in entry lines (8 entries per line).
const DIR_ENTRY_LINES: usize = 256; // 2048 segments

/// Segment-header state values (bits 15..0 of the header word).
const SEG_LIVE: u64 = 1;
const SEG_FREE: u64 = 2;

/// Header-word magic (bits 63..48).
const SEG_MAGIC: u64 = 0x9A5E;

/// Directory-entry magic (bits 63..48); entries pack the segment's line
/// count (bits 47..32) and header word address (bits 31..0).
const ENT_MAGIC: u64 = 0xD1CE;

#[inline]
fn pack_hdr(lines: usize, gen: u64, state: u64) -> u64 {
    (SEG_MAGIC << 48) | ((lines as u64 & 0xFFFF) << 32) | ((gen & 0xFFFF) << 16) | (state & 0xFFFF)
}

/// `(lines, gen, state)` if the word carries the segment magic.
#[inline]
fn unpack_hdr(w: u64) -> Option<(usize, u64, u64)> {
    if w >> 48 != SEG_MAGIC {
        return None;
    }
    Some((((w >> 32) & 0xFFFF) as usize, (w >> 16) & 0xFFFF, w & 0xFFFF))
}

#[inline]
fn pack_ent(lines: usize, hdr: PAddr) -> u64 {
    (ENT_MAGIC << 48) | ((lines as u64 & 0xFFFF) << 32) | hdr.to_u64()
}

#[inline]
fn unpack_ent(w: u64) -> Option<(usize, PAddr)> {
    if w >> 48 != ENT_MAGIC {
        return None;
    }
    Some((((w >> 32) & 0xFFFF) as usize, PAddr((w & 0xFFFF_FFFF) as u32)))
}

/// One size class: the bound segment length and its shared freelist of
/// user-area addresses (overflow/refill tier behind the magazines).
struct ClassState {
    /// Segment length in lines; 0 = unbound.
    lines: AtomicUsize,
    free: Mutex<Vec<u32>>,
    /// Shared-freelist occupancy gauge (lazily registered; name leaked
    /// once per class index per process).
    gauge: OnceLock<std::sync::Arc<obs::Gauge>>,
}

/// Per-thread magazines: one small free-segment cache per class, owned
/// exclusively by its thread (same owner-only contract as the pool's
/// pending-pwb slots).
struct MagazineSlot {
    per_class: UnsafeCell<[Vec<u32>; MAX_CLASSES]>,
}

// SAFETY: `per_class` is accessed only by the owning thread on the
// alloc/free paths; crash rebuild runs strictly after workers unwind.
unsafe impl Sync for MagazineSlot {}

/// Cached registry handles (the global registry lookup takes a lock —
/// too slow for the alloc fast path).
struct Ctrs {
    alloc: std::sync::Arc<obs::Counter>,
    free: std::sync::Arc<obs::Counter>,
    recycled: std::sync::Arc<obs::Counter>,
    leaked: std::sync::Arc<obs::Counter>,
    highwater: std::sync::Arc<obs::Gauge>,
}

fn ctrs() -> &'static Ctrs {
    static C: OnceLock<Ctrs> = OnceLock::new();
    C.get_or_init(|| {
        let r = obs::registry();
        Ctrs {
            alloc: r.counter("persiq_palloc_alloc_total", "Segments handed out by palloc"),
            free: r.counter("persiq_palloc_free_total", "Segments returned to palloc"),
            recycled: r
                .counter("persiq_palloc_recycled_total", "Allocations served from a freelist"),
            leaked: r.counter(
                "persiq_palloc_leaked_total",
                "Segments palloc could not place on a freelist (unknown header or class overflow)",
            ),
            highwater: r.gauge(
                "persiq_palloc_arena_highwater_words",
                "Bump-cursor high-water mark of the pool serving palloc",
            ),
        }
    })
}

fn class_gauge(idx: usize) -> std::sync::Arc<obs::Gauge> {
    static NAMES: OnceLock<Mutex<Vec<(usize, &'static str)>>> = OnceLock::new();
    let names = NAMES.get_or_init(|| Mutex::new(Vec::new()));
    let mut v = names.lock().unwrap_or_else(|e| e.into_inner());
    let name = match v.iter().find(|(i, _)| *i == idx) {
        Some((_, n)) => *n,
        None => {
            let n: &'static str =
                Box::leak(format!("persiq_palloc_class{idx}_free_segments").into_boxed_str());
            v.push((idx, n));
            n
        }
    };
    obs::registry().gauge(name, "Free segments on this palloc size class's shared freelist")
}

/// Volatile allocator state embedded in every [`PmemPool`]. The durable
/// half (segment headers + extent directory) lives in the arena; this
/// struct is rebuilt from it after every crash.
pub struct PallocState {
    /// Extent-directory base (0 = arena too small; palloc degrades to
    /// bump-only, nothing recycles).
    dir: AtomicU32,
    /// Volatile append cursor over directory entry slots.
    next_ent: AtomicUsize,
    classes: [ClassState; MAX_CLASSES],
    mags: Vec<CachePadded<MagazineSlot>>,
    magazine_cap: AtomicUsize,
    recycle: AtomicBool,
    // Per-pool counters (the registry mirrors are process-global).
    n_alloc: AtomicU64,
    n_free: AtomicU64,
    n_recycled: AtomicU64,
    n_leaked: AtomicU64,
    n_recovered_free: AtomicU64,
}

impl PallocState {
    pub(crate) fn new() -> Self {
        Self {
            dir: AtomicU32::new(0),
            next_ent: AtomicUsize::new(0),
            classes: std::array::from_fn(|_| ClassState {
                lines: AtomicUsize::new(0),
                free: Mutex::new(Vec::new()),
                gauge: OnceLock::new(),
            }),
            mags: (0..MAX_THREADS)
                .map(|_| {
                    CachePadded::new(MagazineSlot {
                        per_class: UnsafeCell::new(std::array::from_fn(|_| Vec::new())),
                    })
                })
                .collect(),
            magazine_cap: AtomicUsize::new(DEFAULT_MAGAZINE),
            recycle: AtomicBool::new(true),
            n_alloc: AtomicU64::new(0),
            n_free: AtomicU64::new(0),
            n_recycled: AtomicU64::new(0),
            n_leaked: AtomicU64::new(0),
            n_recovered_free: AtomicU64::new(0),
        }
    }

    /// Per-thread magazine capacity per size class (0 disables magazines;
    /// refills then always go through the shared freelist).
    pub fn set_magazine_cap(&self, cap: usize) {
        self.magazine_cap.store(cap, Ordering::Relaxed);
    }

    /// Enable/disable recycling. Off = every allocation takes the bump
    /// fallback and frees only flip headers (the ablation baseline:
    /// behaviourally identical to the pre-palloc arena).
    pub fn set_recycle(&self, on: bool) {
        self.recycle.store(on, Ordering::Relaxed);
    }

    pub fn recycle_enabled(&self) -> bool {
        self.recycle.load(Ordering::Relaxed)
    }

    /// Segments handed out (fresh + recycled) by this pool's palloc.
    pub fn allocs_total(&self) -> u64 {
        self.n_alloc.load(Ordering::Relaxed)
    }

    /// Segments returned via [`PmemPool::palloc_free`].
    pub fn frees_total(&self) -> u64 {
        self.n_free.load(Ordering::Relaxed)
    }

    /// Allocations served from a magazine or the shared freelist.
    pub fn recycled_total(&self) -> u64 {
        self.n_recycled.load(Ordering::Relaxed)
    }

    /// Frees that could not be placed (bad header / class overflow).
    pub fn leaked_total(&self) -> u64 {
        self.n_leaked.load(Ordering::Relaxed)
    }

    /// Durably-FREE segments recovered onto freelists by crash rebuilds.
    pub fn recovered_free_total(&self) -> u64 {
        self.n_recovered_free.load(Ordering::Relaxed)
    }

    /// Free segments currently on the shared freelist of the class bound
    /// to `lines` (magazine contents not included).
    pub fn free_count(&self, lines: usize) -> usize {
        for c in &self.classes {
            if c.lines.load(Ordering::Relaxed) == lines {
                return c.free.lock().unwrap_or_else(|e| e.into_inner()).len();
            }
        }
        0
    }

    /// `(lines, free-segment count)` for every bound size class, in
    /// class-table order — the per-class occupancy surface for metrics.
    pub fn class_occupancy(&self) -> Vec<(usize, usize)> {
        self.classes
            .iter()
            .filter_map(|c| {
                let lines = c.lines.load(Ordering::Relaxed);
                (lines != 0)
                    .then(|| (lines, c.free.lock().unwrap_or_else(|e| e.into_inner()).len()))
            })
            .collect()
    }

    fn lock_class(&self, idx: usize) -> MutexGuard<'_, Vec<u32>> {
        self.classes[idx].free.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Find (or lazily bind) the class for `lines`. `None` if the table
    /// is full of other sizes.
    fn class_of(&self, lines: usize) -> Option<usize> {
        for (i, c) in self.classes.iter().enumerate() {
            let cur = c.lines.load(Ordering::Relaxed);
            if cur == lines {
                return Some(i);
            }
            if cur == 0
                && c.lines
                    .compare_exchange(0, lines, Ordering::Relaxed, Ordering::Relaxed)
                    .is_ok()
            {
                return Some(i);
            }
            // Lost a binding race: re-check what won the slot.
            if c.lines.load(Ordering::Relaxed) == lines {
                return Some(i);
            }
        }
        None
    }
}

/// Carve the extent directory right after the flight-recorder directory
/// (pool construction; formats with durable pokes like freshly-formatted
/// NVM). Layout: 1 header line (`ENT_MAGIC<<48 | capacity`), then
/// [`DIR_ENTRY_LINES`] lines of 8 entries each.
pub(crate) fn carve_dir(pool: &PmemPool) {
    let Some(base) = pool.try_alloc_lines(1 + DIR_ENTRY_LINES) else {
        return; // tiny arena: bump-only degradation
    };
    pool.poke_durable(base, (ENT_MAGIC << 48) | (DIR_ENTRY_LINES * WORDS_PER_LINE) as u64);
    pool.palloc().dir.store(base.0, Ordering::Release);
}

/// Append a carved segment to the extent directory (durable poke: the
/// directory is discovery metadata, like the flight recorder's). A full
/// directory is not fatal — the segment just becomes invisible to crash
/// rebuilds (conservatively leaked there).
fn dir_append(pool: &PmemPool, hdr: PAddr, lines: usize) {
    let st = pool.palloc();
    let dir = st.dir.load(Ordering::Acquire);
    if dir == 0 {
        return;
    }
    let slot = st.next_ent.fetch_add(1, Ordering::Relaxed);
    if slot >= DIR_ENTRY_LINES * WORDS_PER_LINE {
        return;
    }
    pool.poke_durable(PAddr(dir).add(WORDS_PER_LINE + slot), pack_ent(lines, hdr));
}

/// Allocate a `lines`-line segment for thread `tid`. Fast path: magazine
/// pop + one header store/pwb; slow paths refill from the shared
/// freelist or carve fresh arena. `None` only when the arena is
/// exhausted **and** nothing suitable is free.
pub(crate) fn alloc(pool: &PmemPool, tid: usize, lines: usize) -> Option<PAddr> {
    debug_assert!(lines > 0 && lines <= 0xFFFF);
    let st = pool.palloc();
    if st.recycle.load(Ordering::Relaxed) {
        if let Some(cls) = st.class_of(lines) {
            // (1) Magazine hit: no shared word touched.
            // SAFETY: owner-only access to tid's magazine slot.
            let mag = unsafe { &mut (*st.mags[tid].per_class.get())[cls] };
            let mut got = mag.pop();
            if got.is_none() {
                // (2) Refill from the shared freelist (short volatile
                // critical section — no pmem primitives under the lock).
                let cap = st.magazine_cap.load(Ordering::Relaxed);
                let mut fl = st.lock_class(cls);
                got = fl.pop();
                if got.is_some() {
                    let take = cap.min(fl.len());
                    let at = fl.len() - take;
                    mag.extend(fl.drain(at..));
                    let g = st.classes[cls]
                        .gauge
                        .get_or_init(|| class_gauge(cls))
                        .clone();
                    g.set(0, fl.len() as i64);
                }
            }
            if let Some(addr) = got {
                let user = PAddr(addr);
                let hdr = PAddr(addr - WORDS_PER_LINE as u32);
                let (h_lines, gen, state) =
                    unpack_hdr(pool.peek(hdr)).expect("freelist entry lost its header");
                debug_assert_eq!(h_lines, lines);
                debug_assert_eq!(state, SEG_FREE, "freelist entry must be FREE");
                // Scrub the user area to durable zeros so a recycled
                // segment is indistinguishable from a fresh carve (queues
                // rely on "fresh arena is a valid empty durable
                // structure"). The old contents are durably-consumed
                // garbage — a durably-FREE segment is by contract
                // unreachable — so formatting them away creates no
                // information; zeros only ever read as "absent/torn",
                // the conservative direction. Unmetered, like the
                // freshly-formatted-NVM initialization it reproduces.
                for w in 0..lines * WORDS_PER_LINE {
                    pool.poke_durable(user.add(w), 0);
                }
                // LIVE flip rides the caller's next psync: the pwb is
                // queued on `tid` before the caller can publish any
                // pointer to the segment (see module docs).
                let _g = obs::enter_site(ObsSite::Alloc);
                pool.store(tid, hdr, pack_hdr(lines, gen, SEG_LIVE));
                pool.pwb(tid, hdr);
                drop(_g);
                st.n_alloc.fetch_add(1, Ordering::Relaxed);
                st.n_recycled.fetch_add(1, Ordering::Relaxed);
                let c = ctrs();
                c.alloc.inc(tid);
                c.recycled.inc(tid);
                return Some(user);
            }
        }
    }
    // (3) Fresh carve: header + user area from the bump arena. The
    // header is formatted durably (freshly-formatted-NVM idiom) so the
    // segment is discoverable by crash rebuilds with zero metered
    // traffic on this path — the bump baseline's cost profile.
    let base = pool.try_alloc_lines(1 + lines)?;
    pool.poke_durable(base, pack_hdr(lines, 0, SEG_LIVE));
    dir_append(pool, base, lines);
    st.n_alloc.fetch_add(1, Ordering::Relaxed);
    let c = ctrs();
    c.alloc.inc(tid);
    c.highwater.set(0, pool.used_words() as i64);
    Some(base.add(WORDS_PER_LINE))
}

/// Return the segment whose user area starts at `addr`. The caller must
/// guarantee no thread can still dereference it (grace period + any
/// durable-reachability discipline the structure needs). The `FREE` flip
/// is durable at the caller's next psync; until then a crash
/// conservatively leaks the segment (never double-allocates it).
pub(crate) fn free(pool: &PmemPool, tid: usize, addr: PAddr) {
    let st = pool.palloc();
    if addr.word() < WORDS_PER_LINE {
        st.n_leaked.fetch_add(1, Ordering::Relaxed);
        ctrs().leaked.inc(tid);
        return;
    }
    let hdr = PAddr(addr.0 - WORDS_PER_LINE as u32);
    let Some((lines, gen, state)) = unpack_hdr(pool.peek(hdr)) else {
        // Not a palloc segment (raw bump allocation, or a class-table
        // overflow carve from a future design): leaked-until-audit.
        st.n_leaked.fetch_add(1, Ordering::Relaxed);
        ctrs().leaked.inc(tid);
        return;
    };
    if state != SEG_LIVE {
        debug_assert!(false, "double free of palloc segment at {addr:?}");
        st.n_leaked.fetch_add(1, Ordering::Relaxed);
        ctrs().leaked.inc(tid);
        return;
    }
    {
        let _g = obs::enter_site(ObsSite::Alloc);
        pool.store(tid, hdr, pack_hdr(lines, (gen + 1) & 0xFFFF, SEG_FREE));
        pool.pwb(tid, hdr);
    }
    st.n_free.fetch_add(1, Ordering::Relaxed);
    ctrs().free.inc(tid);
    if !st.recycle.load(Ordering::Relaxed) {
        // Ablation baseline: the header flip still happens (metadata
        // stays honest) but nothing re-enters circulation.
        return;
    }
    match st.class_of(lines) {
        Some(cls) => {
            let cap = st.magazine_cap.load(Ordering::Relaxed);
            // SAFETY: owner-only access to tid's magazine slot.
            let mag = unsafe { &mut (*st.mags[tid].per_class.get())[cls] };
            if mag.len() < cap {
                mag.push(addr.0);
            } else {
                let mut fl = st.lock_class(cls);
                fl.push(addr.0);
                let g = st.classes[cls].gauge.get_or_init(|| class_gauge(cls)).clone();
                g.set(0, fl.len() as i64);
            }
        }
        None => {
            st.n_leaked.fetch_add(1, Ordering::Relaxed);
            ctrs().leaked.inc(tid);
        }
    }
}

/// Post-crash rebuild: discard all volatile freelists/magazines and
/// re-derive them from the durable extent directory in one scan. Runs at
/// the tail of `PmemPool::crash_storage` (live == shadow, workers
/// unwound). Conservative: only durably-`FREE` headers re-enter
/// circulation; everything else is live-or-leaked until audited.
pub(crate) fn rebuild(pool: &PmemPool) {
    let st = pool.palloc();
    for slot in st.mags.iter() {
        // SAFETY: crash time — no workers; same contract as the pool's
        // pending-queue clearing.
        let mags = unsafe { &mut *slot.per_class.get() };
        for m in mags.iter_mut() {
            m.clear();
        }
    }
    for (i, c) in st.classes.iter().enumerate() {
        st.lock_class(i).clear();
        if let Some(g) = c.gauge.get() {
            g.set(0, 0);
        }
    }
    let dir = st.dir.load(Ordering::Acquire);
    if dir == 0 {
        return;
    }
    for slot in 0..DIR_ENTRY_LINES * WORDS_PER_LINE {
        let Some((lines, hdr)) = unpack_ent(pool.peek(PAddr(dir).add(WORDS_PER_LINE + slot)))
        else {
            continue; // hole (torn append) — keep scanning
        };
        let Some((h_lines, _gen, state)) = unpack_hdr(pool.peek(hdr)) else {
            continue; // header torn: conservatively leaked
        };
        if state == SEG_FREE && h_lines == lines {
            if let Some(cls) = st.class_of(lines) {
                let mut fl = st.lock_class(cls);
                fl.push(hdr.0 + WORDS_PER_LINE as u32);
                let len = fl.len() as i64;
                drop(fl);
                if let Some(g) = st.classes[cls].gauge.get() {
                    g.set(0, len);
                }
                st.n_recovered_free.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::PmemConfig;
    use crate::util::rng::Xoshiro256;

    fn pool() -> PmemPool {
        PmemPool::new(PmemConfig {
            capacity_words: 1 << 18,
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            ..PmemConfig::default()
        })
    }

    #[test]
    fn alloc_free_recycles_same_segment() {
        let p = pool();
        let a = p.palloc_alloc(0, 4).unwrap();
        assert_eq!(a.word() % WORDS_PER_LINE, 0, "user area is line aligned");
        p.palloc_free(0, a);
        let b = p.palloc_alloc(0, 4).unwrap();
        assert_eq!(a, b, "magazine hit must return the freed segment");
        assert_eq!(p.palloc().recycled_total(), 1);
        // A different size class carves fresh memory.
        let c = p.palloc_alloc(0, 2).unwrap();
        assert_ne!(c, b);
    }

    #[test]
    fn recycling_bounds_the_bump_cursor() {
        let p = pool();
        let mut last = p.palloc_alloc(0, 8).unwrap();
        let cursor_after_first = p.used_words();
        for _ in 0..1000 {
            p.palloc_free(0, last);
            last = p.palloc_alloc(0, 8).unwrap();
        }
        assert_eq!(p.used_words(), cursor_after_first, "steady churn must not grow the arena");
    }

    #[test]
    fn recycle_off_always_carves() {
        let p = pool();
        p.palloc().set_recycle(false);
        let a = p.palloc_alloc(0, 4).unwrap();
        p.palloc_free(0, a);
        let b = p.palloc_alloc(0, 4).unwrap();
        assert_ne!(a, b, "ablation baseline: bump-only behaviour");
    }

    #[test]
    fn cross_thread_free_flows_through_shared_freelist() {
        let p = pool();
        p.palloc().set_magazine_cap(0); // force the shared tier
        let a = p.palloc_alloc(1, 4).unwrap();
        p.palloc_free(1, a);
        assert_eq!(p.palloc().free_count(4), 1);
        let b = p.palloc_alloc(2, 4).unwrap();
        assert_eq!(a, b, "another thread recycles via the shared freelist");
        assert_eq!(p.palloc().free_count(4), 0);
    }

    #[test]
    fn exhaustion_returns_none_not_panic() {
        let p = PmemPool::new(PmemConfig {
            capacity_words: 1 << 12,
            ..PmemConfig::default()
        });
        let mut n = 0;
        while p.palloc_alloc(0, 8).is_some() {
            n += 1;
            assert!(n < 100_000);
        }
        assert!(n > 0, "some allocations must fit");
    }

    #[test]
    fn durable_free_survives_crash_nondurable_free_is_leaked_not_doubled() {
        let p = pool();
        let kept = p.palloc_alloc(0, 4).unwrap();
        let durable = p.palloc_alloc(0, 4).unwrap();
        let lost = p.palloc_alloc(0, 4).unwrap();
        // Free `durable` and psync (the caller-issued sync the flip
        // piggybacks on); free `lost` with no psync afterwards.
        p.palloc_free(0, durable);
        p.psync(0);
        p.palloc_free(1, lost);
        let mut rng = Xoshiro256::new(7);
        p.crash(&mut rng);
        // Rebuild found exactly the durably-freed segment.
        assert_eq!(p.palloc().recovered_free_total(), 1);
        let back = p.palloc_alloc(0, 4).unwrap();
        assert_eq!(back, durable, "durably-freed segment must be recovered");
        // Nothing else of this class is free: the next alloc carves
        // fresh memory — `lost` is leaked, never double-allocated, and
        // `kept` (still durably LIVE) is untouched.
        let fresh = p.palloc_alloc(0, 4).unwrap();
        assert_ne!(fresh, lost);
        assert_ne!(fresh, kept);
        assert_ne!(fresh, durable);
    }

    #[test]
    fn live_flip_of_recycled_segment_rides_callers_psync() {
        let p = pool();
        let a = p.palloc_alloc(0, 4).unwrap();
        p.palloc_free(0, a);
        p.psync(0); // durable FREE
        let b = p.palloc_alloc(0, 4).unwrap();
        assert_eq!(a, b);
        let hdr = PAddr(b.0 - WORDS_PER_LINE as u32);
        let (_, _, st) = unpack_hdr(p.read_shadow(hdr)).unwrap();
        assert_eq!(st, SEG_FREE, "LIVE flip must not be durable before the caller psyncs");
        p.psync(0);
        let (_, _, st) = unpack_hdr(p.read_shadow(hdr)).unwrap();
        assert_eq!(st, SEG_LIVE, "caller's psync realizes the flip");
    }

    #[test]
    fn alloc_site_pwbs_but_never_psyncs() {
        let p = pool();
        let a = p.palloc_alloc(0, 4).unwrap();
        p.palloc_free(0, a);
        let _ = p.palloc_alloc(0, 4).unwrap();
        let led = p.stats.site_ledger();
        assert_eq!(led.psyncs_at(ObsSite::Alloc), 0, "palloc never issues psyncs");
        assert!(led.pwbs_at(ObsSite::Alloc) >= 2, "state flips are pwb'd at the Alloc site");
    }

    #[test]
    fn header_packing_roundtrip() {
        let w = pack_hdr(37, 5, SEG_FREE);
        assert_eq!(unpack_hdr(w), Some((37, 5, SEG_FREE)));
        assert_eq!(unpack_hdr(0), None);
        let e = pack_ent(9, PAddr(1234));
        assert_eq!(unpack_ent(e), Some((9, PAddr(1234))));
        assert_eq!(unpack_ent(0), None);
    }
}
