//! `persiq::obs` — crate-wide observability: psync attribution, a
//! per-thread metrics registry, bounded JSONL event tracing, and
//! Prometheus-style exposition.
//!
//! The paper's headline result is a persistence-cost accounting (`1/B +
//! 1/K` psyncs per operation pair in steady state; `new_k + 3` per
//! re-shard transition). This module turns that accounting from a proof
//! you re-read into telemetry you can assert:
//!
//! * [`site`] — the [`ObsSite`] attribution scope: every `pwb`/`psync`
//!   the pmem layer executes is charged to the ambient site
//!   (batch-seal, dequeue-flush, resize, plan commit, recovery, broker
//!   ack, or plain per-op), forming the [`SiteLedger`] that
//!   `tests/obs_ledger.rs` checks against the paper's numbers.
//! * [`metrics`] — a register-once registry of per-thread,
//!   cache-line-padded counters/gauges/histograms (relaxed single-writer
//!   increments; snapshot-with-delta aggregation) for signals the pmem
//!   counters don't carry: combiner ring occupancy, flush latency,
//!   broker queue depth, lease reaps, re-shard drain residue.
//! * [`summary`] — the one sample summarizer (exact moments +
//!   nearest-rank percentiles, and the L2 pipeline's histogram-CDF
//!   aggregation) that `util::time` and `runtime::fallback` delegate to.
//! * [`trace`] — bounded per-thread JSONL event rings (`--trace
//!   out.jsonl`): psyncs with sites, batch seals, resize phases, the
//!   recovery timeline, async future lifecycles. Free when disarmed.
//! * [`expo`] — Prometheus text rendering plus the human site-ledger
//!   table (`persiq obs`, `serve --metrics-every N`).
//! * [`flight`] — the **persistent** flight recorder: per-(pool, thread)
//!   NVM-resident event rings that survive the crash, written with
//!   pwb-only traffic piggybacked on the psyncs the algorithms already
//!   issue (zero extra psyncs, asserted by site in `obs_ledger.rs`).
//!   `persiq forensics` scans them post-crash into a merged timeline
//!   and cross-checks recovery's decisions against it.
//!
//! Overhead discipline: with tracing disarmed, the hot-path cost is one
//! padded relaxed load+store per counted event and one relaxed
//! load+branch per trace gate — the observability overhead bench
//! (`benches/obs_overhead.rs`) holds the registry under 5% throughput
//! cost on the fig7 steady-state configuration.

pub mod expo;
pub mod flight;
pub mod metrics;
pub mod site;
pub mod summary;
pub mod trace;

pub use expo::{ledger_families, render, render_site_ledger};
pub use flight::{FlightEvent, FlightKind, FlightRec, PoolScan, RingScan, Timeline};
pub use metrics::{
    registry, set_enabled, Counter, Family, Gauge, HistSnapshot, Histogram, HistogramData, Kind,
    Registry, Sample, Snapshot,
};
pub use site::{
    current_site, enter_site, with_site, ObsSite, SiteGuard, SiteLedger, ALL_SITES, SITE_COUNT,
};
pub use summary::{summarize, summarize_exact, Summary};
