//! Bounded per-thread JSONL event tracing.
//!
//! When armed (`persiq bench --trace out.jsonl`, or [`start`]), typed
//! events are formatted into per-thread bounded rings (drop-oldest under
//! pressure, with a dropped count) and written out — merged and sorted
//! by timestamp — at [`stop`]. When disarmed, every emit call is one
//! relaxed load + branch, so tracing costs nothing on benchmark runs
//! that don't ask for it.
//!
//! Timestamps are **virtual nanoseconds** (the pmem layer's Lamport
//! clocks): a trace lines up with the simulated timeline the benches
//! report, not with wall clock.
//!
//! ## Schema
//!
//! Every line is one JSON object with at least:
//!
//! | key    | type   | meaning                                    |
//! |--------|--------|--------------------------------------------|
//! | `ts`   | u64    | virtual time (ns)                          |
//! | `tid`  | u64    | issuing thread id                          |
//! | `type` | string | event type (below)                         |
//!
//! Per-type required keys:
//!
//! * `"psync"` — `site` (an [`ObsSite`] name), `pool`, `drained`
//! * `"batch_seal"` — `kind` (`"enq"`/`"deq"`), `n`, `pools` (bitmask)
//! * `"span"` — `name`, `start`, `dur` (virtual ns; `ts` is the end)
//! * `"event"` — `name` (plus event-specific fields)
//! * `"future"` — `stage` (`submit|execute|durable|resolve`), `idx`
//!
//! The schema is enforced by `tests/obs_ledger.rs`'s golden-schema
//! check; extend it there when adding event types.

use std::collections::VecDeque;
use std::io::Write as _;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use crossbeam_utils::CachePadded;

use super::site::ObsSite;
use crate::pmem::MAX_THREADS;

/// Default per-thread ring capacity (lines); override with
/// `PERSIQ_TRACE_CAP`.
pub const DEFAULT_RING_CAP: usize = 8192;

static TRACE_ON: AtomicBool = AtomicBool::new(false);

#[derive(Default)]
struct Ring {
    lines: VecDeque<String>,
    dropped: u64,
}

struct TraceState {
    rings: Vec<CachePadded<Mutex<Ring>>>,
    path: Mutex<Option<PathBuf>>,
    cap: AtomicUsize,
}

static STATE: OnceLock<TraceState> = OnceLock::new();

fn state() -> &'static TraceState {
    STATE.get_or_init(|| TraceState {
        rings: (0..MAX_THREADS).map(|_| CachePadded::new(Mutex::new(Ring::default()))).collect(),
        path: Mutex::new(None),
        cap: AtomicUsize::new(DEFAULT_RING_CAP),
    })
}

/// Is tracing armed? One relaxed load — the gate every emit helper
/// checks first.
#[inline]
pub fn enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// Arm tracing, directing the eventual flush to `path`. Clears any
/// previous rings. Ring capacity comes from `PERSIQ_TRACE_CAP` (lines
/// per thread) when set.
pub fn start(path: impl Into<PathBuf>) {
    let st = state();
    if let Ok(v) = std::env::var("PERSIQ_TRACE_CAP") {
        if let Ok(cap) = v.parse::<usize>() {
            st.cap.store(cap.max(16), Ordering::Relaxed);
        }
    }
    for r in &st.rings {
        let mut g = r.lock().unwrap_or_else(|e| e.into_inner());
        g.lines.clear();
        g.dropped = 0;
    }
    *st.path.lock().unwrap() = Some(path.into());
    TRACE_ON.store(true, Ordering::Relaxed);
}

/// Flush summary returned by [`stop`].
#[derive(Clone, Debug)]
pub struct FlushReport {
    pub path: PathBuf,
    pub written: usize,
    pub dropped: u64,
}

/// Disarm tracing and write all buffered events (merged across threads,
/// sorted by `ts`) to the path given at [`start`]. Returns `None` when
/// tracing was never started.
pub fn stop() -> std::io::Result<Option<FlushReport>> {
    TRACE_ON.store(false, Ordering::Relaxed);
    let st = state();
    let Some(path) = st.path.lock().unwrap().take() else {
        return Ok(None);
    };
    let mut all: Vec<String> = Vec::new();
    let mut dropped = 0u64;
    for r in &st.rings {
        let mut g = r.lock().unwrap_or_else(|e| e.into_inner());
        dropped += g.dropped;
        g.dropped = 0;
        all.extend(g.lines.drain(..));
    }
    // Lines start `{"ts":N,...` — sort on the numeric ts prefix so the
    // merged file reads as one timeline.
    all.sort_by_key(|l| parse_ts(l));
    let written = all.len();
    let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
    for l in &all {
        writeln!(f, "{l}")?;
    }
    f.flush()?;
    Ok(Some(FlushReport { path, written, dropped }))
}

fn parse_ts(line: &str) -> u64 {
    line.strip_prefix("{\"ts\":")
        .map(|rest| {
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            digits.parse().unwrap_or(0)
        })
        .unwrap_or(0)
}

fn push(tid: usize, line: String) {
    let st = state();
    let cap = st.cap.load(Ordering::Relaxed);
    let mut g = st.rings[tid % MAX_THREADS].lock().unwrap_or_else(|e| e.into_inner());
    if g.lines.len() >= cap {
        g.lines.pop_front();
        g.dropped += 1;
        crate::obs::registry()
            .counter(
                "persiq_trace_dropped_total",
                "JSONL trace events evicted from a full per-thread ring",
            )
            .inc(tid);
    }
    g.lines.push_back(line);
}

/// Emit a raw event: `fields` is the tail of the JSON object (no
/// braces, no leading comma; empty for none). Prefer the typed helpers.
pub fn emit(tid: usize, ts: u64, typ: &str, fields: std::fmt::Arguments) {
    if !enabled() {
        return;
    }
    let f = fields.to_string();
    let line = if f.is_empty() {
        format!("{{\"ts\":{ts},\"tid\":{tid},\"type\":\"{typ}\"}}")
    } else {
        format!("{{\"ts\":{ts},\"tid\":{tid},\"type\":\"{typ}\",{f}}}")
    };
    push(tid, line);
}

/// A `psync` landed: attribution site, target pool, lines drained.
#[inline]
pub fn psync(tid: usize, ts: u64, site: ObsSite, pool: usize, drained: usize) {
    if !enabled() {
        return;
    }
    emit(
        tid,
        ts,
        "psync",
        format_args!("\"site\":\"{}\",\"pool\":{pool},\"drained\":{drained}", site.name()),
    );
}

/// A batch log sealed: `kind` is `"enq"` or `"deq"`, `n` entries,
/// `pools` the touched-pool bitmask.
#[inline]
pub fn batch_seal(tid: usize, ts: u64, kind: &str, n: usize, pools: u64) {
    if !enabled() {
        return;
    }
    emit(tid, ts, "batch_seal", format_args!("\"kind\":\"{kind}\",\"n\":{n},\"pools\":{pools}"));
}

/// A completed span (resize phases, recovery timeline): `ts` is the end
/// time, `start`/`dur` in virtual ns.
#[inline]
pub fn span(tid: usize, start: u64, end: u64, name: &str, fields: std::fmt::Arguments) {
    if !enabled() {
        return;
    }
    let f = fields.to_string();
    let dur = end.saturating_sub(start);
    if f.is_empty() {
        emit(tid, end, "span", format_args!("\"name\":\"{name}\",\"start\":{start},\"dur\":{dur}"));
    } else {
        emit(
            tid,
            end,
            "span",
            format_args!("\"name\":\"{name}\",\"start\":{start},\"dur\":{dur},{f}"),
        );
    }
}

/// A point event with a name and event-specific fields.
#[inline]
pub fn event(tid: usize, ts: u64, name: &str, fields: std::fmt::Arguments) {
    if !enabled() {
        return;
    }
    let f = fields.to_string();
    if f.is_empty() {
        emit(tid, ts, "event", format_args!("\"name\":\"{name}\""));
    } else {
        emit(tid, ts, "event", format_args!("\"name\":\"{name}\",{f}"));
    }
}

/// An async future lifecycle transition: `stage` ∈
/// `submit|execute|durable|resolve`, `idx` the completion-slot index.
#[inline]
pub fn future_stage(tid: usize, ts: u64, stage: &str, idx: u64) {
    if !enabled() {
        return;
    }
    emit(tid, ts, "future", format_args!("\"stage\":\"{stage}\",\"idx\":{idx}"));
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tracing state is process-global; tests that arm it must not run
    // concurrently with each other. One combined test keeps it simple.
    #[test]
    fn trace_lifecycle_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("persiq_trace_test_{}.jsonl", std::process::id()));

        // Disarmed: emits are dropped for free.
        assert!(!enabled());
        psync(0, 5, ObsSite::Op, 0, 1);

        start(&path);
        assert!(enabled());
        psync(1, 30, ObsSite::BatchFlush, 0, 3);
        batch_seal(1, 20, "enq", 8, 0b1);
        span(0, 10, 50, "resize.stage", format_args!("\"epoch\":2"));
        event(0, 40, "recovery.begin", format_args!(""));
        future_stage(2, 60, "submit", 7);
        let rep = stop().unwrap().expect("was started");
        assert_eq!(rep.written, 5);
        assert_eq!(rep.dropped, 0);
        assert!(!enabled());

        let text = std::fs::read_to_string(&rep.path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        // Sorted by ts across threads.
        let ts: Vec<u64> = lines.iter().map(|l| parse_ts(l)).collect();
        assert_eq!(ts, vec![20, 30, 40, 50, 60], "merged timeline must sort by ts");
        // The disarmed emit did not leak in.
        assert!(!text.contains("\"ts\":5,"));
        // Typed fields present.
        assert!(text.contains("\"type\":\"psync\""));
        assert!(text.contains("\"site\":\"BatchFlush\""));
        assert!(text.contains("\"kind\":\"enq\""));
        assert!(text.contains("\"name\":\"resize.stage\",\"start\":10,\"dur\":40,\"epoch\":2"));
        assert!(text.contains("\"stage\":\"submit\",\"idx\":7"));

        // Restart clears state; ring cap drops oldest.
        start(&path);
        let cap = state().cap.load(Ordering::Relaxed);
        for i in 0..(cap + 10) as u64 {
            event(3, i, "spam", format_args!(""));
        }
        let rep = stop().unwrap().unwrap();
        assert_eq!(rep.written, cap);
        assert_eq!(rep.dropped, 10);
        let _ = std::fs::remove_file(&rep.path);
    }
}
