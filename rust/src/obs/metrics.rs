//! Process-wide metrics registry: per-thread, cache-line-padded
//! counter/gauge/histogram cells.
//!
//! Hot-path cost is one padded relaxed load+store (the same single-writer
//! idiom as [`crate::pmem::stats::OpCounters`]) — no lock-prefixed RMW,
//! no false sharing. Instruments are registered **once** by name
//! ([`Registry::counter`] et al. return the existing instrument on
//! re-registration) and read by summing the per-thread cells at snapshot
//! time. A global kill switch ([`set_enabled`]) turns every instrument
//! into a no-op so the observability overhead bench can compare
//! enabled/disabled in one binary.
//!
//! Aggregated reads come out as Prometheus-shaped [`Family`]s; a
//! [`Snapshot`] supports windowed deltas ([`Snapshot::delta`]) so
//! periodic reporters can print per-interval rates.

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crossbeam_utils::CachePadded;

use crate::pmem::MAX_THREADS;

/// Exponential (base-2) histogram bucket count: bucket 0 holds value 0,
/// bucket `i` holds `[2^(i-1), 2^i)` — 64 buckets cover all of `u64`.
pub const HIST_BUCKETS: usize = 64;

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Globally enable/disable all registry instruments (counters, gauges,
/// histograms). Disabled instruments cost one relaxed load + branch.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Are registry instruments currently recording?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

// Single-writer bump (one thread per cell): plain load+store avoids the
// lock-prefixed RMW on the hot path.
macro_rules! cell_add {
    ($cell:expr, $n:expr) => {{
        let c = $cell;
        let v = c.load(Ordering::Relaxed);
        c.store(v.wrapping_add($n), Ordering::Relaxed);
    }};
}

/// Monotonic counter with one padded cell per thread id.
pub struct Counter {
    cells: Box<[CachePadded<AtomicU64>]>,
}

impl Counter {
    fn new() -> Self {
        Self {
            cells: (0..MAX_THREADS).map(|_| CachePadded::new(AtomicU64::new(0))).collect(),
        }
    }

    /// Add `n` on thread `tid`'s cell.
    #[inline]
    pub fn add(&self, tid: usize, n: u64) {
        if !enabled() {
            return;
        }
        cell_add!(&*self.cells[tid % MAX_THREADS], n);
    }

    /// Increment by one.
    #[inline]
    pub fn inc(&self, tid: usize) {
        self.add(tid, 1);
    }

    /// Sum across all threads.
    pub fn total(&self) -> u64 {
        self.cells.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

/// Gauge: either delta-style (multi-writer [`Gauge::add`]/[`Gauge::sub`],
/// read as the sum of per-thread deltas) or level-style (single logical
/// writer using [`Gauge::set`] on its own cell).
pub struct Gauge {
    cells: Box<[CachePadded<AtomicI64>]>,
}

impl Gauge {
    fn new() -> Self {
        Self {
            cells: (0..MAX_THREADS).map(|_| CachePadded::new(AtomicI64::new(0))).collect(),
        }
    }

    /// Add `n` to thread `tid`'s delta cell.
    #[inline]
    pub fn add(&self, tid: usize, n: i64) {
        if !enabled() {
            return;
        }
        cell_add!(&*self.cells[tid % MAX_THREADS], n);
    }

    /// Subtract `n` from thread `tid`'s delta cell.
    #[inline]
    pub fn sub(&self, tid: usize, n: i64) {
        self.add(tid, -n);
    }

    /// Overwrite thread `tid`'s cell (level-style gauges: the instrument
    /// must then have a single logical writer for `value` to be a level).
    #[inline]
    pub fn set(&self, tid: usize, v: i64) {
        if !enabled() {
            return;
        }
        self.cells[tid % MAX_THREADS].store(v, Ordering::Relaxed);
    }

    /// Sum across all threads.
    pub fn value(&self) -> i64 {
        self.cells.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

struct HistCell {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl HistCell {
    fn new() -> Self {
        Self {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// Bucket index for value `v` (exponential base-2).
#[inline]
fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `i` (`u64::MAX` for the last bucket).
pub fn bucket_bound(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= HIST_BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Exponential histogram with one padded cell set per thread id.
pub struct Histogram {
    cells: Box<[CachePadded<HistCell>]>,
}

impl Histogram {
    fn new() -> Self {
        Self {
            cells: (0..MAX_THREADS).map(|_| CachePadded::new(HistCell::new())).collect(),
        }
    }

    /// Record one observation on thread `tid`'s cell.
    #[inline]
    pub fn record(&self, tid: usize, v: u64) {
        if !enabled() {
            return;
        }
        let c = &self.cells[tid % MAX_THREADS];
        cell_add!(&c.count, 1);
        cell_add!(&c.sum, v);
        cell_add!(&c.buckets[bucket_of(v)], 1);
    }

    /// Aggregate across all threads.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut s = HistSnapshot::default();
        for c in self.cells.iter() {
            s.count += c.count.load(Ordering::Relaxed);
            s.sum += c.sum.load(Ordering::Relaxed);
            for (b, cell) in s.buckets.iter_mut().zip(c.buckets.iter()) {
                *b += cell.load(Ordering::Relaxed);
            }
        }
        s
    }
}

/// Plain-value aggregate of a [`Histogram`].
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub buckets: [u64; HIST_BUCKETS],
}

impl Default for HistSnapshot {
    fn default() -> Self {
        Self { count: 0, sum: 0, buckets: [0; HIST_BUCKETS] }
    }
}

impl HistSnapshot {
    /// Mean observation (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile `q` in `[0, 1]` via the bucket CDF; returns the upper
    /// bound of the bucket containing the target rank (bucket
    /// resolution: one power of two).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                return bucket_bound(i);
            }
        }
        u64::MAX
    }

    /// Windowed delta `self - earlier` (saturating).
    pub fn since(&self, earlier: &HistSnapshot) -> HistSnapshot {
        let mut out = HistSnapshot {
            count: self.count.saturating_sub(earlier.count),
            sum: self.sum.saturating_sub(earlier.sum),
            ..HistSnapshot::default()
        };
        for (i, o) in out.buckets.iter_mut().enumerate() {
            *o = self.buckets[i].saturating_sub(earlier.buckets[i]);
        }
        out
    }
}

/// Metric family kind (the Prometheus `# TYPE`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    pub fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

/// One labelled scalar sample within a family.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Sample {
    /// Unlabelled sample.
    pub fn plain(value: f64) -> Sample {
        Sample { labels: Vec::new(), value }
    }

    /// Single-label sample.
    pub fn labelled(key: &str, val: impl std::fmt::Display, value: f64) -> Sample {
        Sample { labels: vec![(key.to_string(), val.to_string())], value }
    }
}

/// One labelled histogram series within a histogram family.
#[derive(Clone, Debug)]
pub struct HistogramData {
    pub labels: Vec<(String, String)>,
    pub count: u64,
    pub sum: u64,
    /// `(upper_bound, cumulative_count)` pairs in increasing bound order.
    pub buckets: Vec<(f64, u64)>,
}

impl HistogramData {
    /// Convert an aggregate snapshot, collapsing empty tail buckets
    /// (cumulative counts, Prometheus `le` convention).
    pub fn from_snapshot(labels: Vec<(String, String)>, s: &HistSnapshot) -> HistogramData {
        let mut buckets = Vec::new();
        let mut cum = 0u64;
        let last = s.buckets.iter().rposition(|&b| b > 0).unwrap_or(0);
        for (i, b) in s.buckets.iter().enumerate().take(last + 1) {
            cum += b;
            buckets.push((bucket_bound(i) as f64, cum));
        }
        HistogramData { labels, count: s.count, sum: s.sum, buckets }
    }
}

/// A named metric family: samples for counters/gauges, series for
/// histograms.
#[derive(Clone, Debug)]
pub struct Family {
    pub name: String,
    pub help: String,
    pub kind: Kind,
    pub samples: Vec<Sample>,
    pub hists: Vec<HistogramData>,
}

impl Family {
    /// Scalar (counter or gauge) family.
    pub fn scalar(
        name: impl Into<String>,
        help: impl Into<String>,
        kind: Kind,
        samples: Vec<Sample>,
    ) -> Family {
        Family { name: name.into(), help: help.into(), kind, samples, hists: Vec::new() }
    }

    /// Histogram family.
    pub fn histogram(
        name: impl Into<String>,
        help: impl Into<String>,
        hists: Vec<HistogramData>,
    ) -> Family {
        Family {
            name: name.into(),
            help: help.into(),
            kind: Kind::Histogram,
            samples: Vec::new(),
            hists,
        }
    }
}

/// A point-in-time capture of a set of families, supporting windowed
/// deltas for periodic reporters.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub families: Vec<Family>,
}

impl Snapshot {
    /// Windowed delta: counters and histogram counts subtract (matched
    /// by family name + sample labels; samples absent earlier pass
    /// through), gauges keep their current level.
    pub fn delta(&self, earlier: &Snapshot) -> Snapshot {
        let find = |name: &str| earlier.families.iter().find(|f| f.name == name);
        let families = self
            .families
            .iter()
            .map(|f| {
                let mut out = f.clone();
                if f.kind == Kind::Counter {
                    if let Some(e) = find(&f.name) {
                        for s in &mut out.samples {
                            if let Some(es) = e.samples.iter().find(|x| x.labels == s.labels) {
                                s.value -= es.value;
                            }
                        }
                    }
                } else if f.kind == Kind::Histogram {
                    if let Some(e) = find(&f.name) {
                        for h in &mut out.hists {
                            if let Some(eh) = e.hists.iter().find(|x| x.labels == h.labels) {
                                h.count = h.count.saturating_sub(eh.count);
                                h.sum = h.sum.saturating_sub(eh.sum);
                                for (i, (_, c)) in h.buckets.iter_mut().enumerate() {
                                    if let Some((_, ec)) = eh.buckets.get(i) {
                                        *c = c.saturating_sub(*ec);
                                    }
                                }
                            }
                        }
                    }
                }
                out
            })
            .collect();
        Snapshot { families }
    }
}

struct Entry<T> {
    name: &'static str,
    help: &'static str,
    inner: Arc<T>,
}

/// The process-wide registry (register-once by name).
#[derive(Default)]
pub struct Registry {
    counters: Mutex<Vec<Entry<Counter>>>,
    gauges: Mutex<Vec<Entry<Gauge>>>,
    histograms: Mutex<Vec<Entry<Histogram>>>,
}

static REGISTRY: OnceLock<Registry> = OnceLock::new();

/// The process-wide registry.
pub fn registry() -> &'static Registry {
    REGISTRY.get_or_init(Registry::default)
}

impl Registry {
    /// Register (or fetch) the counter `name`.
    pub fn counter(&self, name: &'static str, help: &'static str) -> Arc<Counter> {
        let mut v = self.counters.lock().unwrap();
        if let Some(e) = v.iter().find(|e| e.name == name) {
            return Arc::clone(&e.inner);
        }
        let inner = Arc::new(Counter::new());
        v.push(Entry { name, help, inner: Arc::clone(&inner) });
        inner
    }

    /// Register (or fetch) the gauge `name`.
    pub fn gauge(&self, name: &'static str, help: &'static str) -> Arc<Gauge> {
        let mut v = self.gauges.lock().unwrap();
        if let Some(e) = v.iter().find(|e| e.name == name) {
            return Arc::clone(&e.inner);
        }
        let inner = Arc::new(Gauge::new());
        v.push(Entry { name, help, inner: Arc::clone(&inner) });
        inner
    }

    /// Register (or fetch) the histogram `name`.
    pub fn histogram(&self, name: &'static str, help: &'static str) -> Arc<Histogram> {
        let mut v = self.histograms.lock().unwrap();
        if let Some(e) = v.iter().find(|e| e.name == name) {
            return Arc::clone(&e.inner);
        }
        let inner = Arc::new(Histogram::new());
        v.push(Entry { name, help, inner: Arc::clone(&inner) });
        inner
    }

    /// Aggregate every registered instrument into families (sorted by
    /// name for deterministic output).
    pub fn families(&self) -> Vec<Family> {
        let mut out = Vec::new();
        for e in self.counters.lock().unwrap().iter() {
            out.push(Family::scalar(
                e.name,
                e.help,
                Kind::Counter,
                vec![Sample::plain(e.inner.total() as f64)],
            ));
        }
        for e in self.gauges.lock().unwrap().iter() {
            out.push(Family::scalar(
                e.name,
                e.help,
                Kind::Gauge,
                vec![Sample::plain(e.inner.value() as f64)],
            ));
        }
        for e in self.histograms.lock().unwrap().iter() {
            let s = e.inner.snapshot();
            out.push(Family::histogram(
                e.name,
                e.help,
                vec![HistogramData::from_snapshot(Vec::new(), &s)],
            ));
        }
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    /// Families wrapped as a delta-capable [`Snapshot`].
    pub fn snapshot(&self) -> Snapshot {
        Snapshot { families: self.families() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_sums_across_threads() {
        let c = Counter::new();
        c.inc(0);
        c.add(1, 4);
        c.inc(0);
        assert_eq!(c.total(), 6);
    }

    #[test]
    fn gauge_delta_and_level() {
        let g = Gauge::new();
        g.add(0, 10);
        g.sub(1, 3);
        assert_eq!(g.value(), 7);
        let lvl = Gauge::new();
        lvl.set(2, 42);
        lvl.set(2, 17);
        assert_eq!(lvl.value(), 17);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::new();
        for v in [0u64, 1, 1, 3, 100, 1000] {
            h.record(0, v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 6);
        assert_eq!(s.sum, 1105);
        assert_eq!(s.buckets.iter().sum::<u64>(), 6);
        assert_eq!(s.buckets[0], 1); // the 0
        assert_eq!(s.buckets[1], 2); // the 1s
        // p50 lands in the bucket holding the 3rd ranked value (1).
        assert_eq!(s.quantile(0.5), 1);
        assert!(s.quantile(1.0) >= 1000);
        assert!((s.mean() - 1105.0 / 6.0).abs() < 1e-9);
    }

    #[test]
    fn bucket_bounds_monotone() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        for v in [0u64, 1, 2, 3, 4, 100, 1 << 40] {
            assert!(v <= bucket_bound(bucket_of(v)));
        }
    }

    #[test]
    fn registry_registers_once() {
        let r = Registry::default();
        let a = r.counter("persiq_test_total", "help");
        let b = r.counter("persiq_test_total", "help");
        a.inc(0);
        b.inc(0);
        assert_eq!(a.total(), 2, "same instrument behind both handles");
        let fams = r.families();
        assert_eq!(fams.len(), 1);
        assert_eq!(fams[0].samples[0].value, 2.0);
    }

    #[test]
    fn disabled_instruments_are_noops() {
        let r = Registry::default();
        let c = r.counter("persiq_gate_total", "help");
        set_enabled(false);
        c.inc(0);
        set_enabled(true);
        c.inc(0);
        assert_eq!(c.total(), 1);
    }

    #[test]
    fn snapshot_delta_subtracts_counters() {
        let r = Registry::default();
        let c = r.counter("persiq_delta_total", "help");
        c.add(0, 5);
        let s1 = r.snapshot();
        c.add(0, 3);
        let s2 = r.snapshot();
        let d = s2.delta(&s1);
        assert_eq!(d.families[0].samples[0].value, 3.0);
    }

    #[test]
    fn histogram_delta_windows() {
        let h = Histogram::new();
        h.record(0, 10);
        let s1 = h.snapshot();
        h.record(0, 20);
        h.record(1, 30);
        let s2 = h.snapshot();
        let d = s2.since(&s1);
        assert_eq!(d.count, 2);
        assert_eq!(d.sum, 50);
    }
}
