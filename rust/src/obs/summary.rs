//! The one sample summarizer: every latency/duration aggregation in the
//! repo routes through here so bench tables, service reports and the
//! metrics engine agree on percentile math.
//!
//! Two aggregation families coexist on purpose:
//!
//! * [`moments`] / [`percentile_sorted`] — exact population moments and
//!   nearest-rank percentiles (what `util::time` has always reported;
//!   `util::time::stats` now delegates here).
//! * [`cdf_metrics`] — the L2 pipeline's histogram-CDF aggregation,
//!   relocated **verbatim** from `runtime::fallback` (which now
//!   delegates here). Its quantiles are bucket-resolution approximations
//!   by design: the PJRT artifact computes the same histogram CDF, and
//!   integration tests cross-check the two bit-for-bit-ish. Do not
//!   "fix" its math — change the artifact pipeline first.
//!
//! [`Summary`] packages either path into one shape for reports.

/// Exact population moments over a sample set.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Moments {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

/// Compute [`Moments`] (population std) over `xs`.
pub fn moments(xs: &[f64]) -> Moments {
    if xs.is_empty() {
        return Moments::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    Moments { n, mean, std: var.sqrt(), min, max }
}

/// Percentile (nearest-rank) over a *sorted* slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil().max(1.0) as usize;
    sorted[rank.min(sorted.len()) - 1]
}

/// Compute `(stats[8], hist[nbins])` exactly like the L2 pipeline's
/// `model.metrics` does: filter negatives (padding), normalize to
/// `[min, max)`, `nbins`-bucket histogram, moments from normalized
/// sum/sumsq, quantiles from the histogram CDF. The stats layout is
/// `[count, mean, std, min, max, p50, p95, p99]`.
pub fn cdf_metrics(samples: &[f64], nbins: usize) -> ([f64; 8], Vec<f64>) {
    let valid: Vec<f64> = samples.iter().cloned().filter(|&x| x >= 0.0).collect();
    let count = valid.len() as f64;
    if valid.is_empty() {
        return ([0.0; 8], vec![0.0; nbins]);
    }
    let mn = valid.iter().cloned().fold(f64::INFINITY, f64::min);
    let mx = valid.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let width = (mx - mn).max(1e-6);
    let mut hist = vec![0.0f64; nbins];
    let mut sum = 0.0;
    let mut sumsq = 0.0;
    for &x in &valid {
        let n = (x - mn) / (width * (1.0 + 1e-6));
        let b = ((n * nbins as f64) as usize).min(nbins - 1);
        hist[b] += 1.0;
        sum += n;
        sumsq += n * n;
    }
    let mean_n = sum / count;
    let var_n = (sumsq / count - mean_n * mean_n).max(0.0);
    let mean = mn + mean_n * width;
    let std = var_n.sqrt() * width;
    // Quantiles from the histogram CDF, matching model.metrics.
    let quantile = |p: f64| -> f64 {
        let target = p * count;
        let mut cum = 0.0;
        for (i, h) in hist.iter().enumerate() {
            cum += h;
            if cum >= target {
                return mn + (i as f64 + 1.0) / nbins as f64 * width;
            }
        }
        mx
    };
    (
        [count, mean, std, mn, mx, quantile(0.50), quantile(0.95), quantile(0.99)],
        hist,
    )
}

/// One latency-sample aggregate: the shape shared by bench tables, the
/// service report and the metrics engine's `MetricsOut`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Summary {
    pub count: f64,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// From the `cdf_metrics` stats layout.
    pub fn from_stats(s: [f64; 8]) -> Summary {
        Summary {
            count: s[0],
            mean: s[1],
            std: s[2],
            min: s[3],
            max: s[4],
            p50: s[5],
            p95: s[6],
            p99: s[7],
        }
    }
}

/// Summarize `samples` via the histogram-CDF pipeline (negative entries
/// are padding).
pub fn summarize(samples: &[f64], nbins: usize) -> Summary {
    Summary::from_stats(cdf_metrics(samples, nbins).0)
}

/// Summarize with exact moments and nearest-rank percentiles instead of
/// the CDF approximation (for small sample sets where bucket resolution
/// matters; sorts a copy).
pub fn summarize_exact(samples: &[f64]) -> Summary {
    let mut valid: Vec<f64> = samples.iter().cloned().filter(|&x| x >= 0.0).collect();
    valid.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let m = moments(&valid);
    Summary {
        count: m.n as f64,
        mean: m.mean,
        std: m.std,
        min: if m.n == 0 { 0.0 } else { m.min },
        max: if m.n == 0 { 0.0 } else { m.max },
        p50: percentile_sorted(&valid, 50.0),
        p95: percentile_sorted(&valid, 95.0),
        p99: percentile_sorted(&valid, 99.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_basic() {
        let m = moments(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.n, 4);
        assert!((m.mean - 2.5).abs() < 1e-12);
        assert!((m.std - (1.25f64).sqrt()).abs() < 1e-12);
        assert!((m.min - 1.0).abs() < 1e-12);
        assert!((m.max - 4.0).abs() < 1e-12);
    }

    #[test]
    fn moments_empty() {
        assert_eq!(moments(&[]), Moments::default());
    }

    #[test]
    fn nearest_rank_percentiles() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&v, 50.0), 50.0);
        assert_eq!(percentile_sorted(&v, 95.0), 95.0);
        assert_eq!(percentile_sorted(&v, 100.0), 100.0);
        assert_eq!(percentile_sorted(&[], 50.0), 0.0);
    }

    #[test]
    fn cdf_metrics_matches_legacy_fallback_semantics() {
        // Mirrors runtime::fallback's original unit expectations: the
        // relocation must not change a single bit of this math.
        let samples: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let (s, hist) = cdf_metrics(&samples, 64);
        assert_eq!(s[0], 1000.0);
        assert!((s[1] - 500.5).abs() < 0.5);
        assert!((s[3] - 1.0).abs() < 1e-9);
        assert!((s[4] - 1000.0).abs() < 1e-9);
        assert!((s[5] - 500.0).abs() < 20.0, "p50={}", s[5]);
        assert!((s[6] - 950.0).abs() < 20.0, "p95={}", s[6]);
        assert_eq!(hist.iter().sum::<f64>(), 1000.0);
        // Negative entries are padding.
        let (s, hist) = cdf_metrics(&[-1.0, -1.0], 64);
        assert_eq!(s[0], 0.0);
        assert_eq!(hist.iter().sum::<f64>(), 0.0);
    }

    #[test]
    fn summaries_agree_on_moments() {
        let samples: Vec<f64> = (0..500).map(|i| (i % 97) as f64).collect();
        let a = summarize(&samples, 64);
        let b = summarize_exact(&samples);
        assert_eq!(a.count, b.count);
        assert!((a.mean - b.mean).abs() < 1e-9);
        assert!((a.std - b.std).abs() < 1e-9);
        assert_eq!(a.min, b.min);
        assert_eq!(a.max, b.max);
        // Percentiles agree to one CDF bucket width.
        let bucket = (a.max - a.min) / 64.0 + 1e-9;
        assert!((a.p50 - b.p50).abs() <= bucket + 1.0);
    }
}
