//! Persistent flight recorder: crash-surviving event rings in NVM.
//!
//! PR 6's observability layer is volatile — trace rings and metric cells
//! die at the exact moment they matter most, the simulated crash. This
//! module adds the persistent counterpart: a **per-(pool, thread) event
//! ring carved out of the pool's own arena**, recording compact
//! fixed-width events (op begin, batch/deq seal, plan commit, block
//! seal/drain, broker submit/ack, recovery span) that survive the crash
//! cut and let `persiq forensics` reconstruct *what happened right
//! before the failure* — and cross-check recovery's decisions against
//! it.
//!
//! ## Zero extra psyncs
//!
//! The recorder never issues a `psync` of its own. Every write is a
//! non-metered raw store into the ring plus (for the durable tiers) a
//! metered `pwb` that **piggybacks on a psync the algorithm already
//! issues** — so the paper's per-site psync budgets (`1/B + 1/K`
//! sharded, `new_k + 3` per resize, `~1/block` blockfifo) are untouched,
//! which `tests/obs_ledger.rs` asserts by site. Event stores are
//! deliberately unmetered (like [`crate::pmem::PmemPool::poke`]): they
//! consume no crash-countdown steps and charge no virtual time, so
//! step-swept crash tests and simulated-throughput figures are
//! unchanged; only the real `pwb` cost is modelled (and attributed to
//! the ambient [`crate::obs::ObsSite`] like any other flush).
//!
//! ## Two durability tiers
//!
//! * **Advisory** events ([`FlightKind::OpEnq`], [`FlightKind::OpDeq`],
//!   [`FlightKind::RecoverBegin`]) are recorded at operation time with
//!   plain stores. Their ring lines are `pwb`ed by [`presync`] —
//!   called by the group-commit flush *immediately before* its seal
//!   psync — so a completed seal deterministically drains them
//!   (a `psync` realizes **every** queued flush of the calling thread).
//! * **Sealed** events (batch/deq seals, plan commits, block
//!   seals/drains, broker submit/ack, recovery end) are written
//!   **after** their certifying psync returns, then `pwb`ed to ride the
//!   *next* psync (or the crash-time eviction race). Write-after-psync
//!   is the soundness keystone: if a sealed event is readable from the
//!   shadow (NVM) image at all — via a later psync *or* a lucky
//!   crash-time flush — its psync already completed, so the state it
//!   describes is durable.
//!
//! Combining the two: a durable flush-seal event with ring sequence `S`
//! certifies **every** same-ring event with sequence `< S` (their lines
//! were queued before the seal's psync, which drains deterministically).
//! That is the invariant the crash-sweep property test
//! (`tests/prop_flight.rs`) and the `persiq forensics` cross-check lean
//! on: no certified-durable op is ever lost, no certified-durable
//! dequeue reappears, and any survivor missing from the ring sits
//! beyond the open ring tail (its batch's seal psync never completed).
//!
//! ## On-NVM layout and crash semantics of the ring itself
//!
//! Each pool carves a **directory** (1 header line + `MAX_THREADS` base
//! slots) as its very first line-aligned allocation, giving it the
//! well-known address [`DIR_BASE`]; per-thread rings (1 header line +
//! [`RING_ENTRIES`] four-word entries) are carved lazily on first
//! record. Directory/ring headers are formatted into live *and* shadow
//! at carve time ("freshly formatted NVM" — carving is metadata, not
//! algorithm state, and must be discoverable even if only luck flushed
//! the first events). Entries are checksummed (`w3 = w0^w1^w2^SALT`),
//! so fresh all-zero slots and torn tails read as absent; the header
//! cursor is `pwb`ed alongside the entries as a scan hint. Ring wrap
//! overwrites the oldest entries and bumps
//! `persiq_flight_overwritten_total`.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};

use crate::pmem::{PAddr, PmemPool, Topology, MAX_THREADS, WORDS_PER_LINE};

/// Directory header magic ("FLITDIR",1). Word 0 of [`DIR_BASE`].
pub const DIR_MAGIC: u64 = 0x464C_4954_4449_5201;
/// Ring header magic ("FLITRNG",1). Word 0 of every per-thread ring.
pub const RING_MAGIC: u64 = 0x464C_4954_524E_4701;
/// Entry checksum salt: makes the all-zero (never written) entry fail
/// validation, so fresh rings scan as empty.
const ENTRY_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

/// Events per ring. A full ring keeps the last `RING_ENTRIES` events of
/// one thread on one pool — sized to hold several batch windows of
/// history around the crash cut.
pub const RING_ENTRIES: usize = 64;
/// Words per entry: `[seq, kind|tid|clock, payload, checksum]`.
const ENTRY_WORDS: usize = 4;
/// Ring footprint: 1 header line + the entry lines.
const RING_LINES: usize = 1 + RING_ENTRIES * ENTRY_WORDS / WORDS_PER_LINE;
/// Directory footprint: 1 header line + one base-address word per tid.
const DIR_LINES: usize = 1 + MAX_THREADS / WORDS_PER_LINE;
/// Pools smaller than this get no recorder (unit-test arenas): the
/// directory + a few rings must never crowd out the algorithm's data.
pub const MIN_CAPACITY_WORDS: usize = 1 << 14;
/// The directory's well-known address: the first line-aligned
/// allocation of a fresh pool (the bump cursor starts at word 1).
pub const DIR_BASE: PAddr = PAddr(WORDS_PER_LINE as u32);

const CLOCK_MASK: u64 = (1 << 48) - 1;
/// Entry word 0 packs `crash_epoch << 48 | seq`: certification must not
/// cross a crash boundary (a post-recovery seal could otherwise
/// retroactively certify a pre-crash entry whose line luck-landed at
/// the cut while its operation's log line did not).
const SEQ_MASK: u64 = (1 << 48) - 1;

/// Process-wide logical clock stamped into every event: merges rings
/// from different pools/threads into one timeline. Volatile by design —
/// it survives *simulated* crashes (same process) and falls back to
/// per-ring sequence order across real restarts.
static LCLOCK: AtomicU64 = AtomicU64::new(1);

/// Recorder kill switch (default on). `benches/obs_overhead.rs` turns it
/// off in the baseline arm so the < 5% gate covers the recorder's cost.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Is the flight recorder recording?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Enable/disable recording (carving is unaffected: the directory is
/// always formatted so layouts don't shift with the toggle).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// What a flight-recorder event describes.
///
/// *Advisory* kinds are recorded before their durability point and are
/// certified only by a later same-ring flush seal; every other kind is
/// written **after** its certifying psync returned, so its presence in
/// the shadow image alone proves the state it describes durable.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum FlightKind {
    /// Enqueue recorded into a batch log (payload = item). Advisory.
    OpEnq = 1,
    /// Dequeue recorded into a dequeue log (payload = item). Advisory.
    OpDeq = 2,
    /// Enqueue batch sealed + psynced (payload = ops sealed).
    BatchSeal = 3,
    /// Dequeue batch sealed + psynced (payload = ops sealed).
    DeqSeal = 4,
    /// Plan-log commit psync retired (payload = [`plan_payload`]).
    PlanCommit = 5,
    /// BlockFIFO block sealed COMMITTED (payload = [`block_payload`]).
    BlockSeal = 6,
    /// BlockFIFO block claimed DRAINING (payload = [`block_payload`]).
    BlockDrain = 7,
    /// Broker job record + submit-log append psynced (payload = job id).
    BrokerSubmit = 8,
    /// Broker DONE mark psynced (payload = job id).
    BrokerAck = 9,
    /// Recovery started (payload = crash epoch). Advisory.
    RecoverBegin = 10,
    /// Recovery finished; all recovery psyncs precede this write
    /// (payload = crash epoch).
    RecoverEnd = 11,
}

impl FlightKind {
    /// Decode a stored kind byte.
    pub fn from_u8(v: u8) -> Option<FlightKind> {
        Some(match v {
            1 => FlightKind::OpEnq,
            2 => FlightKind::OpDeq,
            3 => FlightKind::BatchSeal,
            4 => FlightKind::DeqSeal,
            5 => FlightKind::PlanCommit,
            6 => FlightKind::BlockSeal,
            7 => FlightKind::BlockDrain,
            8 => FlightKind::BrokerSubmit,
            9 => FlightKind::BrokerAck,
            10 => FlightKind::RecoverBegin,
            11 => FlightKind::RecoverEnd,
            _ => return None,
        })
    }

    /// Recorded before the durability point (certified only by a later
    /// same-ring flush seal)?
    pub fn advisory(self) -> bool {
        matches!(self, FlightKind::OpEnq | FlightKind::OpDeq | FlightKind::RecoverBegin)
    }

    /// A group-commit seal written immediately after a psync that was
    /// immediately preceded by [`presync`] — the only kinds whose
    /// durability certifies *all lower-sequence entries of the ring*.
    pub fn flush_seal(self) -> bool {
        matches!(self, FlightKind::BatchSeal | FlightKind::DeqSeal)
    }

    /// Stable display name.
    pub fn name(self) -> &'static str {
        match self {
            FlightKind::OpEnq => "op_enq",
            FlightKind::OpDeq => "op_deq",
            FlightKind::BatchSeal => "batch_seal",
            FlightKind::DeqSeal => "deq_seal",
            FlightKind::PlanCommit => "plan_commit",
            FlightKind::BlockSeal => "block_seal",
            FlightKind::BlockDrain => "block_drain",
            FlightKind::BrokerSubmit => "broker_submit",
            FlightKind::BrokerAck => "broker_ack",
            FlightKind::RecoverBegin => "recover_begin",
            FlightKind::RecoverEnd => "recover_end",
        }
    }
}

/// Pack a plan-commit payload: `epoch` (40 bits), new shard count `k`
/// (16 bits), transition `phase` (0 = record, 1 = freeze, 2 = retire).
pub fn plan_payload(epoch: u64, k: usize, phase: u8) -> u64 {
    (epoch << 24) | ((k as u64 & 0xFFFF) << 8) | phase as u64
}

/// Unpack [`plan_payload`] → `(epoch, k, phase)`.
pub fn plan_unpack(p: u64) -> (u64, usize, u8) {
    (p >> 24, ((p >> 8) & 0xFFFF) as usize, (p & 0xFF) as u8)
}

/// Pack a block event payload: `lane` (16 bits), block `idx` (32 bits),
/// entry `count` (16 bits).
pub fn block_payload(lane: usize, idx: usize, count: u64) -> u64 {
    ((lane as u64 & 0xFFFF) << 48) | ((idx as u64 & 0xFFFF_FFFF) << 16) | (count & 0xFFFF)
}

/// Unpack [`block_payload`] → `(lane, idx, count)`.
pub fn block_unpack(p: u64) -> (usize, usize, u64) {
    ((p >> 48) as usize, ((p >> 16) & 0xFFFF_FFFF) as usize, p & 0xFFFF)
}

/// Per-pool volatile recorder state, embedded in every
/// [`PmemPool`]. Tracks the carved directory, each thread's ring base,
/// and each thread's write/flush cursors. All interior-mutable: pool
/// methods take `&self`, and each per-thread slot is written only by
/// its owning thread (the pool's usual tid-exclusivity contract).
pub struct FlightRec {
    /// Directory header word index (0 = pool too small, recorder off).
    dir: AtomicU32,
    /// Per-thread ring base cache (mirrors the durable directory slot).
    rings: Box<[AtomicU32]>,
    /// Per-thread last written sequence number (seq starts at 1).
    seqs: Box<[AtomicU64]>,
    /// Per-thread highest seq whose line has been `pwb`-queued.
    flushed: Box<[AtomicU64]>,
    /// Ring-wrap overwrites on this pool (also a registry counter).
    overwritten: AtomicU64,
}

impl FlightRec {
    pub(crate) fn new() -> FlightRec {
        FlightRec {
            dir: AtomicU32::new(0),
            rings: (0..MAX_THREADS).map(|_| AtomicU32::new(0)).collect(),
            seqs: (0..MAX_THREADS).map(|_| AtomicU64::new(0)).collect(),
            flushed: (0..MAX_THREADS).map(|_| AtomicU64::new(0)).collect(),
            overwritten: AtomicU64::new(0),
        }
    }

    /// Does this pool have a recorder directory?
    pub fn present(&self) -> bool {
        self.dir.load(Ordering::Acquire) != 0
    }

    /// Ring-wrap overwrites recorded on this pool so far.
    pub fn overwritten(&self) -> u64 {
        self.overwritten.load(Ordering::Relaxed)
    }
}

/// Format the per-pool directory as the pool's **first** allocation
/// (called from pool construction, before any other carve): header +
/// base slots land at the well-known [`DIR_BASE`], written straight
/// into live *and* shadow ("formatted NVM", no metered traffic, no
/// psyncs — construction-site budgets stay zero).
pub(crate) fn carve_dir(pool: &PmemPool) {
    if pool.capacity_words() < MIN_CAPACITY_WORDS {
        return;
    }
    let Some(dir) = pool.try_alloc_lines(DIR_LINES) else { return };
    debug_assert_eq!(dir, DIR_BASE, "flight directory must be the first allocation");
    pool.poke_durable(dir, DIR_MAGIC);
    pool.poke_durable(dir.add(1), 1); // layout version
    pool.poke_durable(dir.add(2), RING_ENTRIES as u64);
    pool.flight().dir.store(dir.word() as u32, Ordering::Release);
}

/// Lazily carve `tid`'s ring on this pool (first record only). The base
/// slot + ring header are formatted durably, so a once-carved ring is
/// always discoverable by the scanner.
fn ensure_ring(pool: &PmemPool, tid: usize) -> Option<PAddr> {
    if tid >= MAX_THREADS {
        return None;
    }
    let fr = pool.flight();
    let cached = fr.rings[tid].load(Ordering::Relaxed);
    if cached != 0 {
        return Some(PAddr(cached));
    }
    let dirw = fr.dir.load(Ordering::Acquire);
    if dirw == 0 {
        return None;
    }
    let base = pool.try_alloc_lines(RING_LINES)?;
    pool.poke_durable(base, RING_MAGIC);
    pool.poke_durable(base.add(1), tid as u64);
    pool.poke_durable(PAddr(dirw).add(WORDS_PER_LINE + tid), base.to_u64());
    fr.rings[tid].store(base.0, Ordering::Release);
    Some(base)
}

#[inline]
fn entry_addr(base: PAddr, seq: u64) -> PAddr {
    let slot = ((seq - 1) % RING_ENTRIES as u64) as usize;
    base.add(WORDS_PER_LINE + slot * ENTRY_WORDS)
}

/// Write one entry with plain (unmetered) stores. Returns its seq.
fn write_entry(pool: &PmemPool, base: PAddr, tid: usize, kind: FlightKind, payload: u64) -> u64 {
    let fr = pool.flight();
    let seq = fr.seqs[tid].load(Ordering::Relaxed) + 1;
    fr.seqs[tid].store(seq, Ordering::Relaxed);
    if seq as usize > RING_ENTRIES {
        fr.overwritten.fetch_add(1, Ordering::Relaxed);
        crate::obs::registry()
            .counter(
                "persiq_flight_overwritten_total",
                "flight-recorder ring entries overwritten by ring wrap",
            )
            .inc(tid);
        // Header word 3: this ring's overwrite count (pwb'd with the
        // cursor at the next flush point).
        pool.poke(base.add(3), seq - RING_ENTRIES as u64);
    }
    let a = entry_addr(base, seq);
    let clock = LCLOCK.fetch_add(1, Ordering::Relaxed) & CLOCK_MASK;
    let w0 = ((pool.epoch() & 0xFFFF) << 48) | (seq & SEQ_MASK);
    let w1 = ((kind as u64) << 56) | ((tid as u64 & 0xFF) << 48) | clock;
    pool.poke(a, w0);
    pool.poke(a.add(1), w1);
    pool.poke(a.add(2), payload);
    pool.poke(a.add(3), w0 ^ w1 ^ payload ^ ENTRY_SALT);
    seq
}

/// `pwb` every entry line not yet queued (plus the header cursor), so
/// they ride the caller's next psync. Idempotent; no-op when clean.
fn pwb_backlog(pool: &PmemPool, tid: usize) {
    let fr = pool.flight();
    let basew = fr.rings[tid].load(Ordering::Relaxed);
    if basew == 0 {
        return;
    }
    let base = PAddr(basew);
    let cur = fr.seqs[tid].load(Ordering::Relaxed);
    let fl = fr.flushed[tid].load(Ordering::Relaxed);
    if cur == fl {
        return;
    }
    // Only the live window can need flushing (older slots were
    // overwritten); dedupe adjacent same-line entries — the pending set
    // dedupes too, this just avoids re-charging the pwb cost.
    let lo = (fl.max(cur.saturating_sub(RING_ENTRIES as u64))) + 1;
    let mut last_line = usize::MAX;
    for s in lo..=cur {
        let a = entry_addr(base, s);
        if a.line() != last_line {
            last_line = a.line();
            pool.pwb(tid, a);
        }
    }
    pool.poke(base.add(2), cur); // cursor: scan hint, best effort
    pool.pwb(tid, base);
    fr.flushed[tid].store(cur, Ordering::Relaxed);
}

/// Record an **advisory** event (plain stores only — zero metered
/// traffic). Its line is `pwb`ed by the next [`presync`]/[`record_sealed`]
/// on this (pool, tid), riding that flush's psync.
#[inline]
pub fn record_advisory(pool: &PmemPool, tid: usize, kind: FlightKind, payload: u64) {
    if !enabled() {
        return;
    }
    let Some(base) = ensure_ring(pool, tid) else { return };
    write_entry(pool, base, tid, kind, payload);
}

/// Record a **sealed** event: call only *after* the psync that makes
/// the described state durable has returned. Writes the entry, then
/// `pwb`s it (and any advisory backlog) to ride the next psync — the
/// write-after-psync order is what makes a durable sealed event
/// trustworthy on its own.
pub fn record_sealed(pool: &PmemPool, tid: usize, kind: FlightKind, payload: u64) {
    if !enabled() {
        return;
    }
    let Some(base) = ensure_ring(pool, tid) else { return };
    write_entry(pool, base, tid, kind, payload);
    pwb_backlog(pool, tid);
}

/// Queue the ring's dirty lines behind the caller's upcoming psync.
/// Group-commit flush paths call this immediately before their seal
/// psync so the advisory ops of the batch become durable *with* the
/// seal — the piggyback that keeps the recorder at zero extra psyncs.
#[inline]
pub fn presync(pool: &PmemPool, tid: usize) {
    if !enabled() {
        return;
    }
    pwb_backlog(pool, tid);
}

// ---------------------------------------------------------------------
// Post-crash scanning + timeline reconstruction
// ---------------------------------------------------------------------

/// One decoded, checksum-valid event from a ring's shadow image.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlightEvent {
    /// Pool (socket) the ring lives on.
    pub socket: usize,
    pub tid: usize,
    /// Per-ring sequence number (from 1; monotonic per (pool, tid),
    /// continuing across crashes).
    pub seq: u64,
    /// Crash epoch (topology crash count) the event was recorded in.
    pub epoch: u64,
    /// Process-wide logical clock (merge order within one process run).
    pub clock: u64,
    pub kind: FlightKind,
    pub payload: u64,
}

/// Scan of one thread's ring (shadow image — what survived the crash).
#[derive(Clone, Debug, Default)]
pub struct RingScan {
    pub tid: usize,
    /// Checksum-valid events, ascending seq.
    pub events: Vec<FlightEvent>,
    /// Slots with data that failed validation (torn tail entries).
    pub torn: usize,
    /// Durable overwrite count from the ring header.
    pub overwritten: u64,
    /// Durable header cursor (scan hint; the events themselves rule).
    pub cursor: u64,
    /// Highest durable flush-seal seq of the newest epoch: advisory
    /// events above it are the ring's **open tail** (the in-flight
    /// window at the cut).
    pub last_certified_seq: u64,
    /// Per crash epoch, the highest durable flush-seal seq. A seal only
    /// certifies lower-seq entries of its *own* epoch: a pre-crash
    /// entry that luck-landed at the cut must not be blessed by a
    /// post-recovery seal.
    pub seal_max: std::collections::BTreeMap<u64, u64>,
}

impl RingScan {
    /// Is `e` (an event of this ring) certified durable — i.e. does its
    /// durability prove the operation it describes durable?
    pub fn certified(&self, e: &FlightEvent) -> bool {
        !e.kind.advisory()
            || self.seal_max.get(&e.epoch).is_some_and(|&m| e.seq <= m)
    }
}

/// Scan of one pool's recorder region.
#[derive(Clone, Debug, Default)]
pub struct PoolScan {
    pub socket: usize,
    /// Directory magic found in the shadow image?
    pub present: bool,
    pub rings: Vec<RingScan>,
}

/// Scan one pool's shadow (NVM) image for flight data. Works on any
/// pool image: the directory is at the well-known [`DIR_BASE`] and
/// self-identifies by magic.
pub fn scan_pool(pool: &PmemPool) -> PoolScan {
    let mut ps = PoolScan { socket: pool.socket(), present: false, rings: Vec::new() };
    if pool.capacity_words() < MIN_CAPACITY_WORDS || pool.read_shadow(DIR_BASE) != DIR_MAGIC {
        return ps;
    }
    ps.present = true;
    for t in 0..MAX_THREADS {
        let bw = pool.read_shadow(DIR_BASE.add(WORDS_PER_LINE + t));
        if bw == 0 {
            continue;
        }
        let base = PAddr::from_u64(bw);
        if pool.read_shadow(base) != RING_MAGIC {
            continue;
        }
        let mut ring = RingScan {
            tid: t,
            cursor: pool.read_shadow(base.add(2)),
            overwritten: pool.read_shadow(base.add(3)),
            ..Default::default()
        };
        for slot in 0..RING_ENTRIES {
            let a = base.add(WORDS_PER_LINE + slot * ENTRY_WORDS);
            let (w0, w1, w2, w3) = (
                pool.read_shadow(a),
                pool.read_shadow(a.add(1)),
                pool.read_shadow(a.add(2)),
                pool.read_shadow(a.add(3)),
            );
            if w0 == 0 && w1 == 0 && w2 == 0 && w3 == 0 {
                continue; // never written
            }
            if w3 != w0 ^ w1 ^ w2 ^ ENTRY_SALT {
                ring.torn += 1;
                continue;
            }
            let Some(kind) = FlightKind::from_u8((w1 >> 56) as u8) else {
                ring.torn += 1;
                continue;
            };
            ring.events.push(FlightEvent {
                socket: ps.socket,
                tid: t,
                seq: w0 & SEQ_MASK,
                epoch: w0 >> 48,
                clock: w1 & CLOCK_MASK,
                kind,
                payload: w2,
            });
        }
        ring.events.sort_by_key(|e| e.seq);
        for e in ring.events.iter().filter(|e| e.kind.flush_seal()) {
            let m = ring.seal_max.entry(e.epoch).or_insert(0);
            *m = (*m).max(e.seq);
        }
        ring.last_certified_seq = ring.seal_max.values().copied().max().unwrap_or(0);
        ps.rings.push(ring);
    }
    ps
}

/// Scan every pool of a topology (call after the crash, **before**
/// recovery mutates the image).
pub fn scan(topo: &Topology) -> Vec<PoolScan> {
    topo.pools().iter().map(|p| scan_pool(p)).collect()
}

/// Per-thread digest of the merged timeline.
#[derive(Clone, Debug, Default)]
pub struct ThreadLine {
    pub tid: usize,
    /// Certified-durable enqueued items (advisory OpEnq under a seal).
    pub durable_enqs: Vec<u64>,
    /// Certified-durable dequeued items.
    pub durable_deqs: Vec<u64>,
    /// Advisory events past the last certifying seal: the thread's
    /// in-flight window at the cut (durability uncertain).
    pub inflight: Vec<FlightEvent>,
    /// The last certified event of the thread (any kind), by clock.
    pub last_durable: Option<FlightEvent>,
    /// Certified seal-tier events (batch/deq/plan/block/broker).
    pub seals: usize,
    pub torn: usize,
    pub overwritten: u64,
}

/// Merged reconstruction across all pools' rings.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    /// Every valid event, ordered by logical clock (then socket, seq).
    pub events: Vec<FlightEvent>,
    /// Per-tid digests (only tids that recorded anything).
    pub threads: Vec<ThreadLine>,
    /// Certified plan commits, decoded `(epoch, k, phase)`.
    pub plan_commits: Vec<(u64, usize, u8)>,
    /// Certified broker submit payloads (job ids).
    pub broker_submits: Vec<u64>,
    /// Certified broker ack payloads (job ids).
    pub broker_acks: Vec<u64>,
    /// Certified block seals/drains, decoded `(lane, idx, count)`.
    pub block_seals: Vec<(usize, usize, u64)>,
    pub block_drains: Vec<(usize, usize, u64)>,
    /// Recovery spans seen (RecoverEnd events — completed recoveries).
    pub recoveries: usize,
    pub torn: usize,
    pub overwritten: u64,
}

/// Build the merged timeline from per-pool scans.
pub fn timeline(scans: &[PoolScan]) -> Timeline {
    let mut tl = Timeline::default();
    let mut lines: std::collections::BTreeMap<usize, ThreadLine> = Default::default();
    for ps in scans {
        for ring in &ps.rings {
            let line = lines.entry(ring.tid).or_insert_with(|| ThreadLine {
                tid: ring.tid,
                ..Default::default()
            });
            line.torn += ring.torn;
            line.overwritten += ring.overwritten;
            tl.torn += ring.torn;
            tl.overwritten += ring.overwritten;
            for e in &ring.events {
                tl.events.push(*e);
                if ring.certified(e) {
                    match e.kind {
                        FlightKind::OpEnq => line.durable_enqs.push(e.payload),
                        FlightKind::OpDeq => line.durable_deqs.push(e.payload),
                        FlightKind::PlanCommit => {
                            tl.plan_commits.push(plan_unpack(e.payload));
                            line.seals += 1;
                        }
                        FlightKind::BrokerSubmit => {
                            tl.broker_submits.push(e.payload);
                            line.seals += 1;
                        }
                        FlightKind::BrokerAck => {
                            tl.broker_acks.push(e.payload);
                            line.seals += 1;
                        }
                        FlightKind::BlockSeal => {
                            tl.block_seals.push(block_unpack(e.payload));
                            line.seals += 1;
                        }
                        FlightKind::BlockDrain => {
                            tl.block_drains.push(block_unpack(e.payload));
                            line.seals += 1;
                        }
                        FlightKind::RecoverEnd => {
                            tl.recoveries += 1;
                            line.seals += 1;
                        }
                        FlightKind::BatchSeal | FlightKind::DeqSeal => line.seals += 1,
                        FlightKind::RecoverBegin => {}
                    }
                    if line.last_durable.map(|p| p.clock < e.clock).unwrap_or(true) {
                        line.last_durable = Some(*e);
                    }
                } else {
                    line.inflight.push(*e);
                }
            }
        }
    }
    tl.events.sort_by_key(|e| (e.clock, e.socket, e.tid, e.seq));
    tl.threads = lines.into_values().collect();
    tl
}

/// Result of cross-checking a timeline against post-recovery truth.
#[derive(Clone, Debug, Default)]
pub struct CrossCheck {
    /// Certified-durable enqueues checked (invariant A).
    pub durable_enqs: usize,
    /// Certified-durable dequeues checked (invariant B).
    pub durable_deqs: usize,
    /// Survivors found recorded in the rings (certified or open-tail).
    pub survivors_recorded: usize,
    /// Survivors absent from the rings — each must sit beyond the open
    /// ring tail (its seal psync never completed); counted, not a
    /// violation.
    pub survivors_unrecorded: usize,
    /// Human-readable invariant violations (empty = clean).
    pub violations: Vec<String>,
}

impl CrossCheck {
    /// Zero unexplained discrepancies?
    pub fn pass(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Cross-check a queue timeline against recovered truth:
///
/// * **A** — every certified-durable `OpEnq` item survives (it is in the
///   post-recovery drain), was already returned to a caller before the
///   crash, or is certified durably consumed (the cut can land after a
///   deq seal's psync but before the value reaches the caller): a
///   recorded-durable op is never lost.
/// * **B** — no certified-durable `OpDeq` item reappears among the
///   survivors: a durably-logged consumption is never redelivered.
///
/// `survivors` = items drained from the recovered queue; `returned` =
/// items dequeue calls returned before the cut (both sets of raw item
/// values).
pub fn crosscheck_queue(
    tl: &Timeline,
    survivors: &std::collections::HashSet<u64>,
    returned: &std::collections::HashSet<u64>,
) -> CrossCheck {
    let mut cc = CrossCheck::default();
    let mut recorded: std::collections::HashSet<u64> = Default::default();
    let consumed: std::collections::HashSet<u64> = tl
        .threads
        .iter()
        .flat_map(|l| l.durable_deqs.iter().copied())
        .collect();
    for line in &tl.threads {
        for &item in &line.durable_enqs {
            cc.durable_enqs += 1;
            recorded.insert(item);
            if !survivors.contains(&item)
                && !returned.contains(&item)
                && !consumed.contains(&item)
            {
                cc.violations.push(format!(
                    "A: durable enqueue of item {item} (tid {}) lost by recovery",
                    line.tid
                ));
            }
        }
        for &item in &line.durable_deqs {
            cc.durable_deqs += 1;
            if survivors.contains(&item) {
                cc.violations.push(format!(
                    "B: durably-dequeued item {item} (tid {}) redelivered after recovery",
                    line.tid
                ));
            }
        }
        for e in &line.inflight {
            if e.kind == FlightKind::OpEnq {
                recorded.insert(e.payload);
            }
        }
    }
    for &s in survivors {
        if recorded.contains(&s) {
            cc.survivors_recorded += 1;
        } else {
            cc.survivors_unrecorded += 1;
        }
    }
    cc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::PmemConfig;

    fn quiet_pool(words: usize) -> PmemPool {
        PmemPool::new(PmemConfig::default().with_capacity(words))
    }

    #[test]
    fn directory_at_well_known_base() {
        let pool = quiet_pool(1 << 16);
        assert!(pool.flight().present());
        assert_eq!(pool.read_shadow(DIR_BASE), DIR_MAGIC);
        assert_eq!(pool.peek(DIR_BASE), DIR_MAGIC);
        // Fresh pool: scanner finds the directory, no rings, no events.
        let ps = scan_pool(&pool);
        assert!(ps.present);
        assert!(ps.rings.is_empty());
    }

    #[test]
    fn tiny_pools_opt_out() {
        let pool = quiet_pool(1 << 12);
        assert!(!pool.flight().present());
        record_advisory(&pool, 0, FlightKind::OpEnq, 7); // must be a no-op
        assert!(!scan_pool(&pool).present);
        // The arena is untouched by the recorder.
        assert_eq!(pool.alloc_lines(1), DIR_BASE);
    }

    #[test]
    fn advisory_events_ride_the_next_psync() {
        let pool = quiet_pool(1 << 16);
        for i in 0..3 {
            record_advisory(&pool, 0, FlightKind::OpEnq, 100 + i);
        }
        // Not yet durable: plain stores only.
        assert!(scan_pool(&pool).rings.is_empty() || scan_pool(&pool).rings[0].events.is_empty());
        presync(&pool, 0);
        pool.psync(0);
        let ps = scan_pool(&pool);
        assert_eq!(ps.rings.len(), 1);
        let ring = &ps.rings[0];
        assert_eq!(ring.events.len(), 3);
        assert_eq!(ring.cursor, 3);
        // No flush seal yet: everything is open tail.
        assert_eq!(ring.last_certified_seq, 0);
        assert!(!ring.certified(&ring.events[0]));
    }

    #[test]
    fn flush_seal_certifies_the_prefix() {
        let pool = quiet_pool(1 << 16);
        for i in 0..4 {
            record_advisory(&pool, 1, FlightKind::OpEnq, 200 + i);
        }
        presync(&pool, 1);
        pool.psync(1); // the "batch seal" psync
        record_sealed(&pool, 1, FlightKind::BatchSeal, 4);
        pool.psync(1); // any later psync carries the seal event
        let ps = scan_pool(&pool);
        let ring = &ps.rings[0];
        assert_eq!(ring.events.len(), 5);
        assert_eq!(ring.last_certified_seq, 5);
        for e in &ring.events {
            assert!(ring.certified(e));
        }
        let tl = timeline(&[ps.clone()]);
        assert_eq!(tl.threads.len(), 1);
        assert_eq!(tl.threads[0].durable_enqs, vec![200, 201, 202, 203]);
        assert_eq!(tl.threads[0].seals, 1);
        assert!(tl.threads[0].inflight.is_empty());
    }

    #[test]
    fn recorder_adds_pwbs_but_never_psyncs() {
        let pool = quiet_pool(1 << 16);
        let before = pool.stats.total();
        for i in 0..8 {
            record_advisory(&pool, 0, FlightKind::OpEnq, i);
        }
        let mid = pool.stats.total();
        assert_eq!(mid.pwbs, before.pwbs, "advisory records must not issue pwbs");
        assert_eq!(mid.psyncs, before.psyncs);
        presync(&pool, 0);
        record_sealed(&pool, 0, FlightKind::BatchSeal, 8);
        let after = pool.stats.total();
        assert!(after.pwbs > mid.pwbs);
        assert_eq!(after.psyncs, before.psyncs, "the recorder must never psync");
    }

    #[test]
    fn ring_wrap_counts_overwrites_and_keeps_the_window() {
        let pool = quiet_pool(1 << 16);
        let n = RING_ENTRIES as u64 + 10;
        for i in 0..n {
            record_advisory(&pool, 0, FlightKind::OpEnq, i);
        }
        presync(&pool, 0);
        pool.psync(0);
        record_sealed(&pool, 0, FlightKind::BatchSeal, n);
        pool.psync(0);
        assert_eq!(pool.flight().overwritten(), 11); // 10 advisory + 1 seal past the wrap
        let ps = scan_pool(&pool);
        let ring = &ps.rings[0];
        assert_eq!(ring.events.len(), RING_ENTRIES);
        assert_eq!(ring.overwritten, 11);
        // The window is the newest RING_ENTRIES seqs, seal included.
        assert_eq!(ring.events.last().unwrap().seq, n + 1);
        assert_eq!(ring.events.first().unwrap().seq, n + 2 - RING_ENTRIES as u64);
    }

    #[test]
    fn torn_entries_are_rejected() {
        let pool = quiet_pool(1 << 16);
        record_advisory(&pool, 0, FlightKind::OpEnq, 1);
        presync(&pool, 0);
        pool.psync(0);
        // Corrupt the durable entry's payload without fixing the checksum.
        let base = PAddr(pool.flight().rings[0].load(Ordering::Relaxed));
        let a = base.add(WORDS_PER_LINE + 2);
        pool.poke(a, 0xDEAD);
        pool.pwb(0, a);
        pool.psync(0);
        let ps = scan_pool(&pool);
        assert_eq!(ps.rings[0].events.len(), 0);
        assert_eq!(ps.rings[0].torn, 1);
    }

    #[test]
    fn crosscheck_flags_lost_and_redelivered() {
        let pool = quiet_pool(1 << 16);
        record_advisory(&pool, 0, FlightKind::OpEnq, 11);
        record_advisory(&pool, 0, FlightKind::OpEnq, 12);
        record_advisory(&pool, 0, FlightKind::OpDeq, 11);
        presync(&pool, 0);
        pool.psync(0);
        record_sealed(&pool, 0, FlightKind::DeqSeal, 1);
        pool.psync(0);
        let tl = timeline(&scan_pool(&pool).into());
        let survivors: std::collections::HashSet<u64> = [12].into_iter().collect();
        let returned: std::collections::HashSet<u64> = [11].into_iter().collect();
        let cc = crosscheck_queue(&tl, &survivors, &returned);
        assert!(cc.pass(), "clean history must cross-check: {:?}", cc.violations);
        // Lose item 12 → invariant A fires.
        let cc = crosscheck_queue(&tl, &Default::default(), &returned);
        assert!(!cc.pass());
        // Redeliver the durably-dequeued 11 → invariant B fires.
        let bad: std::collections::HashSet<u64> = [11, 12].into_iter().collect();
        let cc = crosscheck_queue(&tl, &bad, &Default::default());
        assert!(cc.violations.iter().any(|v| v.starts_with("B:")));
    }

    #[test]
    fn payload_packing_roundtrips() {
        assert_eq!(plan_unpack(plan_payload(7, 16, 2)), (7, 16, 2));
        assert_eq!(block_unpack(block_payload(3, 12345, 16)), (3, 12345, 16));
    }

    impl From<PoolScan> for Vec<PoolScan> {
        fn from(p: PoolScan) -> Vec<PoolScan> {
            vec![p]
        }
    }
}
