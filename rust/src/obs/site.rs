//! Persistence-instruction attribution sites.
//!
//! The paper's headline claim is a *cost accounting*: a batched sharded
//! queue spends `1/B + 1/K` psyncs per enqueue/dequeue pair, and a
//! re-shard transition spends exactly `new_k + 3`. Totals alone cannot
//! check that — a stray flush hidden in a resize path would vanish into
//! the per-op noise. Every `pwb`/`psync` is therefore attributed to the
//! [`ObsSite`] that issued it, forming a per-site **persistence ledger**
//! ([`SiteLedger`]) that `tests/obs_ledger.rs` asserts against the
//! paper's numbers.
//!
//! Attribution uses an ambient thread-local scope rather than a site
//! parameter on every pmem primitive: high-level code wraps a region in
//! [`with_site`] (or holds an [`enter_site`] guard) and every
//! persistence instruction issued from the current thread inside that
//! region is charged to the site. Base queue algorithms (LCRQ, PerLCRQ,
//! the durable MS queue, …) stay untouched; the sharding, async and
//! broker layers — where the paper's accounting lives — set the scope.
//! Outside any scope the site is [`ObsSite::Op`]: ordinary per-operation
//! persistence.

use std::cell::Cell;

/// Which logical code path issued a persistence instruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum ObsSite {
    /// Ordinary per-operation persistence (the default scope): shared
    /// queue-variable pwbs, unbatched per-op psyncs, submit-log appends.
    Op = 0,
    /// Structure construction: initial stripe roots, plan-log init,
    /// broker/job-record layout.
    Setup = 1,
    /// Group-commit seal of an **enqueue** batch log (the `1/B` term).
    BatchFlush = 2,
    /// Group-commit seal of a dequeue-only batch log (the `1/K` term).
    DeqFlush = 3,
    /// Re-shard transition work outside the plan log: fresh stripe
    /// construction (one psync per new stripe).
    Resize = 4,
    /// Plan-log commit points: record + freeze + retire (the `+3`).
    PlanCommit = 5,
    /// Post-crash recovery and reconciliation (must be 0 in steady
    /// state).
    Recovery = 6,
    /// Broker job-completion acks (CAS to DONE + flush), including the
    /// async flusher's exec-batch drains that realize them.
    BrokerAck = 7,
    /// Allocator metadata persistence: segment-header state flips issued
    /// by `pmem::palloc` (alloc→LIVE, free→FREE). These are pwb-only —
    /// durability piggybacks on psyncs the caller already issues, so the
    /// ledger must show **zero** psyncs at this site in steady state.
    Alloc = 8,
}

/// Number of [`ObsSite`] variants (ledger array length).
pub const SITE_COUNT: usize = 9;

/// Every site, in discriminant order (ledger index order).
pub const ALL_SITES: [ObsSite; SITE_COUNT] = [
    ObsSite::Op,
    ObsSite::Setup,
    ObsSite::BatchFlush,
    ObsSite::DeqFlush,
    ObsSite::Resize,
    ObsSite::PlanCommit,
    ObsSite::Recovery,
    ObsSite::BrokerAck,
    ObsSite::Alloc,
];

impl ObsSite {
    /// Ledger array index (the discriminant).
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    /// Stable display/label name.
    pub fn name(self) -> &'static str {
        match self {
            ObsSite::Op => "Op",
            ObsSite::Setup => "Setup",
            ObsSite::BatchFlush => "BatchFlush",
            ObsSite::DeqFlush => "DeqFlush",
            ObsSite::Resize => "Resize",
            ObsSite::PlanCommit => "PlanCommit",
            ObsSite::Recovery => "Recovery",
            ObsSite::BrokerAck => "BrokerAck",
            ObsSite::Alloc => "Alloc",
        }
    }
}

impl std::fmt::Display for ObsSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

thread_local! {
    static CURRENT_SITE: Cell<u8> = const { Cell::new(0) };
}

/// The calling thread's ambient attribution site ([`ObsSite::Op`] when
/// no scope is active).
#[inline]
pub fn current_site() -> ObsSite {
    CURRENT_SITE.with(|c| ALL_SITES[c.get() as usize])
}

/// RAII scope guard: restores the previous site on drop — including
/// unwinds, which matters because a `psync` can unwind with a simulated
/// crash signal mid-scope.
pub struct SiteGuard {
    prev: u8,
}

impl Drop for SiteGuard {
    fn drop(&mut self) {
        CURRENT_SITE.with(|c| c.set(self.prev));
    }
}

/// Enter `site` for the calling thread until the returned guard drops.
#[must_use = "the site scope ends when the guard drops"]
pub fn enter_site(site: ObsSite) -> SiteGuard {
    let prev = CURRENT_SITE.with(|c| {
        let p = c.get();
        c.set(site as u8);
        p
    });
    SiteGuard { prev }
}

/// Run `f` with the calling thread's attribution scope set to `site`.
pub fn with_site<R>(site: ObsSite, f: impl FnOnce() -> R) -> R {
    let _g = enter_site(site);
    f()
}

/// Aggregated per-site persistence-instruction counts (indices follow
/// [`ALL_SITES`]). Filled from pmem pool stats; asserted by the site
/// ledger test; rendered by [`crate::obs::expo`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SiteLedger {
    pub psyncs: [u64; SITE_COUNT],
    pub pwbs: [u64; SITE_COUNT],
}

impl SiteLedger {
    /// Elementwise accumulate.
    pub fn add(&mut self, o: &SiteLedger) {
        for (a, b) in self.psyncs.iter_mut().zip(o.psyncs.iter()) {
            *a += b;
        }
        for (a, b) in self.pwbs.iter_mut().zip(o.pwbs.iter()) {
            *a += b;
        }
    }

    /// psyncs attributed to `site`.
    #[inline]
    pub fn psyncs_at(&self, site: ObsSite) -> u64 {
        self.psyncs[site.index()]
    }

    /// pwbs attributed to `site`.
    #[inline]
    pub fn pwbs_at(&self, site: ObsSite) -> u64 {
        self.pwbs[site.index()]
    }

    /// Total psyncs across all sites (equals the untyped psync counter).
    pub fn total_psyncs(&self) -> u64 {
        self.psyncs.iter().sum()
    }

    /// Total pwbs across all sites.
    pub fn total_pwbs(&self) -> u64 {
        self.pwbs.iter().sum()
    }

    /// Ledger delta `self - earlier` (saturating; for phase windows).
    pub fn since(&self, earlier: &SiteLedger) -> SiteLedger {
        let mut out = SiteLedger::default();
        for (i, o) in out.psyncs.iter_mut().enumerate() {
            *o = self.psyncs[i].saturating_sub(earlier.psyncs[i]);
        }
        for (i, o) in out.pwbs.iter_mut().enumerate() {
            *o = self.pwbs[i].saturating_sub(earlier.pwbs[i]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_scope_is_op() {
        assert_eq!(current_site(), ObsSite::Op);
    }

    #[test]
    fn scope_nests_and_restores() {
        assert_eq!(current_site(), ObsSite::Op);
        with_site(ObsSite::Resize, || {
            assert_eq!(current_site(), ObsSite::Resize);
            with_site(ObsSite::PlanCommit, || {
                assert_eq!(current_site(), ObsSite::PlanCommit);
            });
            assert_eq!(current_site(), ObsSite::Resize);
        });
        assert_eq!(current_site(), ObsSite::Op);
    }

    #[test]
    fn scope_restores_on_unwind() {
        let r = std::panic::catch_unwind(|| {
            let _g = enter_site(ObsSite::Recovery);
            panic!("boom");
        });
        assert!(r.is_err());
        assert_eq!(current_site(), ObsSite::Op);
    }

    #[test]
    fn scope_is_thread_local() {
        let _g = enter_site(ObsSite::BrokerAck);
        std::thread::spawn(|| {
            assert_eq!(current_site(), ObsSite::Op);
        })
        .join()
        .unwrap();
        assert_eq!(current_site(), ObsSite::BrokerAck);
    }

    #[test]
    fn indices_match_all_sites() {
        for (i, s) in ALL_SITES.iter().enumerate() {
            assert_eq!(s.index(), i);
        }
        assert_eq!(ALL_SITES.len(), SITE_COUNT);
    }

    #[test]
    fn ledger_arithmetic() {
        let mut a = SiteLedger::default();
        a.psyncs[ObsSite::BatchFlush.index()] = 5;
        a.pwbs[ObsSite::Op.index()] = 7;
        let mut b = SiteLedger::default();
        b.psyncs[ObsSite::BatchFlush.index()] = 2;
        b.add(&a);
        assert_eq!(b.psyncs_at(ObsSite::BatchFlush), 7);
        assert_eq!(b.total_psyncs(), 7);
        assert_eq!(b.total_pwbs(), 7);
        let d = b.since(&a);
        assert_eq!(d.psyncs_at(ObsSite::BatchFlush), 2);
        assert_eq!(d.pwbs_at(ObsSite::Op), 0);
    }
}
