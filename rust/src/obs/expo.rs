//! Prometheus-style text exposition and the psync-by-site ledger table.
//!
//! [`render`] produces the classic text format (`# HELP` / `# TYPE` /
//! `name{labels} value`, histograms as `_bucket`/`_sum`/`_count`) from
//! [`Family`]s — whether they come from the global registry or from a
//! structure's `metric_families()` collector. [`render_site_ledger`]
//! prints the per-site persistence ledger as a human table: the view
//! that makes the paper's `1/B + 1/K` accounting visible at a glance.

use super::metrics::{Family, Kind, Sample};
use super::site::{SiteLedger, ALL_SITES};

fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{v:.0}")
    } else {
        format!("{v}")
    }
}

fn fmt_labels(labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", v.replace('\\', "\\\\").replace('"', "\\\"")))
        .collect();
    format!("{{{}}}", inner.join(","))
}

fn fmt_le(bound: f64) -> String {
    if bound == u64::MAX as f64 || bound.is_infinite() {
        "+Inf".to_string()
    } else {
        fmt_value(bound)
    }
}

/// Render families as Prometheus text exposition format.
pub fn render(families: &[Family]) -> String {
    let mut out = String::new();
    for f in families {
        out.push_str(&format!("# HELP {} {}\n", f.name, f.help));
        out.push_str(&format!("# TYPE {} {}\n", f.name, f.kind.as_str()));
        match f.kind {
            Kind::Counter | Kind::Gauge => {
                for s in &f.samples {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        f.name,
                        fmt_labels(&s.labels),
                        fmt_value(s.value)
                    ));
                }
            }
            Kind::Histogram => {
                for h in &f.hists {
                    for (le, cum) in &h.buckets {
                        let mut labels = h.labels.clone();
                        labels.push(("le".to_string(), fmt_le(*le)));
                        out.push_str(&format!(
                            "{}_bucket{} {}\n",
                            f.name,
                            fmt_labels(&labels),
                            cum
                        ));
                    }
                    let mut inf = h.labels.clone();
                    inf.push(("le".to_string(), "+Inf".to_string()));
                    out.push_str(&format!(
                        "{}_bucket{} {}\n",
                        f.name,
                        fmt_labels(&inf),
                        h.count
                    ));
                    out.push_str(&format!(
                        "{}_sum{} {}\n",
                        f.name,
                        fmt_labels(&h.labels),
                        h.sum
                    ));
                    out.push_str(&format!(
                        "{}_count{} {}\n",
                        f.name,
                        fmt_labels(&h.labels),
                        h.count
                    ));
                }
            }
        }
    }
    out
}

/// The per-site persistence ledger as Prometheus families
/// (`persiq_pmem_psyncs_by_site_total` / `persiq_pmem_pwbs_by_site_total`).
pub fn ledger_families(ledger: &SiteLedger) -> Vec<Family> {
    let psyncs = ALL_SITES
        .iter()
        .map(|s| Sample::labelled("site", s.name(), ledger.psyncs_at(*s) as f64))
        .collect();
    let pwbs = ALL_SITES
        .iter()
        .map(|s| Sample::labelled("site", s.name(), ledger.pwbs_at(*s) as f64))
        .collect();
    vec![
        Family::scalar(
            "persiq_pmem_psyncs_by_site_total",
            "psync instructions by attribution site",
            Kind::Counter,
            psyncs,
        ),
        Family::scalar(
            "persiq_pmem_pwbs_by_site_total",
            "pwb instructions by attribution site",
            Kind::Counter,
            pwbs,
        ),
    ]
}

/// Human-readable site-ledger table. `op_pairs` (completed
/// enqueue+dequeue pairs) adds a psyncs-per-op-pair column when
/// non-zero — the direct check against the paper's `1/B + 1/K` claim.
pub fn render_site_ledger(ledger: &SiteLedger, op_pairs: u64) -> String {
    let mut out = String::new();
    out.push_str("site         psyncs       pwbs");
    if op_pairs > 0 {
        out.push_str("   psyncs/op-pair");
    }
    out.push('\n');
    for s in ALL_SITES {
        let p = ledger.psyncs_at(s);
        let w = ledger.pwbs_at(s);
        if op_pairs > 0 {
            out.push_str(&format!(
                "{:<11} {:>7} {:>10}   {:>14.6}\n",
                s.name(),
                p,
                w,
                p as f64 / op_pairs as f64
            ));
        } else {
            out.push_str(&format!("{:<11} {:>7} {:>10}\n", s.name(), p, w));
        }
    }
    let (tp, tw) = (ledger.total_psyncs(), ledger.total_pwbs());
    if op_pairs > 0 {
        out.push_str(&format!(
            "{:<11} {:>7} {:>10}   {:>14.6}\n",
            "TOTAL",
            tp,
            tw,
            tp as f64 / op_pairs as f64
        ));
    } else {
        out.push_str(&format!("{:<11} {:>7} {:>10}\n", "TOTAL", tp, tw));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::{HistSnapshot, HistogramData};
    use crate::obs::site::ObsSite;

    #[test]
    fn renders_scalar_families() {
        let fams = vec![
            Family::scalar(
                "persiq_ops_total",
                "total ops",
                Kind::Counter,
                vec![Sample::labelled("pool", 0, 42.0), Sample::labelled("pool", 1, 7.0)],
            ),
            Family::scalar(
                "persiq_depth",
                "queue depth",
                Kind::Gauge,
                vec![Sample::plain(3.0)],
            ),
        ];
        let text = render(&fams);
        assert!(text.contains("# HELP persiq_ops_total total ops"));
        assert!(text.contains("# TYPE persiq_ops_total counter"));
        assert!(text.contains("persiq_ops_total{pool=\"0\"} 42"));
        assert!(text.contains("persiq_ops_total{pool=\"1\"} 7"));
        assert!(text.contains("# TYPE persiq_depth gauge"));
        assert!(text.contains("persiq_depth 3"));
    }

    #[test]
    fn renders_histograms_with_inf_bucket() {
        let mut buckets = [0u64; crate::obs::metrics::HIST_BUCKETS];
        buckets[1] = 2;
        buckets[3] = 1;
        let s = HistSnapshot { count: 3, sum: 12, buckets };
        let fams = vec![Family::histogram(
            "persiq_lat_ns",
            "latency",
            vec![HistogramData::from_snapshot(Vec::new(), &s)],
        )];
        let text = render(&fams);
        assert!(text.contains("# TYPE persiq_lat_ns histogram"));
        assert!(text.contains("persiq_lat_ns_bucket{le=\"1\"} 2"));
        assert!(text.contains("persiq_lat_ns_bucket{le=\"7\"} 3"));
        assert!(text.contains("persiq_lat_ns_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("persiq_lat_ns_sum 12"));
        assert!(text.contains("persiq_lat_ns_count 3"));
    }

    #[test]
    fn ledger_table_and_families() {
        let mut l = SiteLedger::default();
        l.psyncs[ObsSite::BatchFlush.index()] = 10;
        l.psyncs[ObsSite::Op.index()] = 2;
        l.pwbs[ObsSite::Op.index()] = 100;
        let table = render_site_ledger(&l, 100);
        assert!(table.contains("BatchFlush"));
        assert!(table.contains("psyncs/op-pair"));
        assert!(table.contains("TOTAL"));
        let plain = render_site_ledger(&l, 0);
        assert!(!plain.contains("psyncs/op-pair"));
        let fams = ledger_families(&l);
        let text = render(&fams);
        assert!(text.contains("persiq_pmem_psyncs_by_site_total{site=\"BatchFlush\"} 10"));
        assert!(text.contains("persiq_pmem_pwbs_by_site_total{site=\"Op\"} 100"));
    }

    #[test]
    fn label_escaping() {
        let fams = vec![Family::scalar(
            "persiq_esc",
            "h",
            Kind::Gauge,
            vec![Sample::labelled("k", "a\"b\\c", 1.0)],
        )];
        let text = render(&fams);
        assert!(text.contains("persiq_esc{k=\"a\\\"b\\\\c\"} 1"));
    }
}
