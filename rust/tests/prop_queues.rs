//! Property tests (mini-framework; see verify/proptest.rs): randomized
//! workloads + randomized crash points over every persistent queue must
//! satisfy durable linearizability; randomized pmem programs must satisfy
//! the epoch-persistency axioms.

use std::sync::Arc;

use persiq::harness::runner::{drain_all, run_workload, RunConfig};
use persiq::harness::Workload;
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::{PmemConfig, PmemPool};
use persiq::queues::{persistent_registry, QueueConfig, QueueCtx};
use persiq::util::rng::Xoshiro256;
use persiq::verify::proptest::{forall, PropConfig};
use persiq::verify::{
    check, check_with, options_for, relaxation_for, CheckOptions, History,
};

#[test]
fn prop_durable_linearizability_under_random_crashes() {
    install_quiet_crash_hook();
    forall(PropConfig { cases: 6, seed: 0xDEED }, |rng, _case| {
        let nthreads = 2 + rng.next_below(3) as usize; // 2..4
        let ring = 1usize << rng.range_inclusive(4, 8); // 16..256
        let workload = *rng.choose(&[Workload::Pairs, Workload::Random5050]);
        let cycles = 1 + rng.next_below(3); // 1..3
        for (name, ctor) in persistent_registry() {
            let mut qcfg = QueueConfig { ring_size: ring, ..Default::default() };
            if name.starts_with("blockfifo") {
                // Blockfifo reuses ring_size as the per-lane block count,
                // and block claims are never recycled (infinite-array
                // tier): the random small ring would exhaust mid-run, so
                // size the lanes to the whole multi-cycle workload.
                qcfg.ring_size = 1 << 12;
            }
            let ctx = QueueCtx::single(
                PmemConfig {
                    capacity_words: 1 << 23,
                    evict_prob: rng.next_f64() * 0.5,
                    pending_flush_prob: rng.next_f64(),
                    seed: rng.next_u64(),
                    ..Default::default()
                },
                nthreads,
                qcfg,
            );
            let q = ctor(&ctx);
            let qc: Arc<dyn persiq::queues::ConcurrentQueue> = Arc::clone(&q) as _;
            let mut crash_rng = Xoshiro256::seed_from(rng.next_u64());
            let mut logs = Vec::new();
            for cycle in 0..cycles {
                ctx.topo.arm_crash_after(5_000 + rng.next_below(25_000));
                let r = run_workload(
                    &ctx.topo,
                    &qc,
                    &RunConfig {
                        nthreads,
                        total_ops: 30_000,
                        workload,
                        record: true,
                        salt: cycle + 1,
                        seed: rng.next_u64(),
                        ..Default::default()
                    },
                );
                logs.extend(r.logs);
                ctx.topo.crash(&mut crash_rng);
                q.recover(ctx.pool());
            }
            let drained = drain_all(&qc, 0);
            let h = History::from_logs(logs, drained);
            // Every cycle ended in a crash: options_for opens exactly the
            // algorithm's crash-gated windows (batched/blocked tails) on
            // those epochs and nothing else.
            let rep = check_with(&h, &options_for(name, nthreads, &ctx.cfg, cycles));
            if !rep.ok() {
                return Err(format!("{name}: {:?}", rep.violations));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_crash_during_dequeue_batch_reconciles_exactly() {
    // Consumer-side group commit: kill workers mid-batch (the crash lands
    // at arbitrary pmem primitives, including inside a flush's psync) and
    // assert the verifier accepts exactly the reconciled history — no
    // enqueued value lost, no duplicate delivery beyond the K−1 per-thread
    // trailing-redelivery window of each crashed epoch, and the absorbed
    // redeliveries stay within the hard bound the contract promises.
    install_quiet_crash_hook();
    forall(PropConfig { cases: 8, seed: 0xDEC0DE }, |rng, _case| {
        let nthreads = 2 + rng.next_below(3) as usize; // 2..4
        let shards = 1 + rng.next_below(4) as usize; // 1..4
        let batch = *rng.choose(&[1usize, 2, 4, 8]);
        let batch_deq = *rng.choose(&[2usize, 4, 8]); // always batched deqs
        let cycles = 1 + rng.next_below(3); // 1..3
        // Half the cases run on a 2-pool topology with a random placement
        // policy: the crash can land between the flush's per-pool psyncs
        // (one pool's drain realized, the sibling's lost) — exactly the
        // cross-pool window reconciliation must close.
        let pools = *rng.choose(&[1usize, 2, 2]);
        let placement = if pools == 1 {
            persiq::pmem::PlacementPolicy::Interleave
        } else {
            rng.choose(&[
                persiq::pmem::PlacementPolicy::Interleave,
                persiq::pmem::PlacementPolicy::Colocate,
                persiq::pmem::PlacementPolicy::Pinned(vec![1, 0]),
            ])
            .clone()
        };
        let ctx = QueueCtx {
            topo: persiq::pmem::Topology::new(
                PmemConfig {
                    capacity_words: 1 << 23,
                    evict_prob: rng.next_f64() * 0.5,
                    pending_flush_prob: rng.next_f64(),
                    seed: rng.next_u64(),
                    ..Default::default()
                },
                pools,
            ),
            nthreads,
            cfg: QueueConfig {
                shards,
                batch,
                batch_deq,
                ring_size: 128,
                placement,
                ..Default::default()
            },
        };
        let q = persiq::queues::persistent_by_name("sharded-perlcrq").unwrap()(&ctx);
        let qc: Arc<dyn persiq::queues::ConcurrentQueue> = Arc::clone(&q) as _;
        let mut crash_rng = Xoshiro256::seed_from(rng.next_u64());
        let mut logs = Vec::new();
        for cycle in 0..cycles {
            ctx.topo.arm_crash_after(4_000 + rng.next_below(20_000));
            let r = run_workload(
                &ctx.topo,
                &qc,
                &RunConfig {
                    nthreads,
                    total_ops: 30_000,
                    workload: Workload::Pairs,
                    record: true,
                    salt: cycle + 1,
                    seed: rng.next_u64(),
                    ..Default::default()
                },
            );
            logs.extend(r.logs);
            ctx.topo.crash(&mut crash_rng);
            q.recover(ctx.pool());
        }
        let drained = drain_all(&qc, 0);
        let h = History::from_logs(logs, drained);
        let opts = CheckOptions {
            max_report: 10,
            relaxation: relaxation_for("sharded-perlcrq", nthreads, &ctx.cfg),
            trailing_loss_per_thread: batch - 1,
            trailing_redelivery_per_thread: batch_deq - 1,
            crashed_epochs: cycles,
            check_empty: batch <= 1,
            ..Default::default()
        };
        let rep = check_with(&h, &opts);
        if !rep.ok() {
            return Err(format!(
                "shards={shards} batch={batch} batch_deq={batch_deq}: {:?} \
                 (max_overtakes={})",
                rep.violations, rep.max_overtakes
            ));
        }
        // Exactness: the allowance is a hard per-thread-per-epoch bound.
        let cap = (batch_deq - 1) * nthreads * cycles as usize;
        if rep.absorbed_redelivered > cap {
            return Err(format!(
                "absorbed {} redeliveries, contract caps at {cap}",
                rep.absorbed_redelivered
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_pmem_epoch_persistency_axioms() {
    // Random programs of stores/pwbs/psyncs; after a crash:
    //  (a) psync'd values are always visible;
    //  (b) every surviving value was actually stored at some point
    //      (no invention);
    //  (c) with evict_prob = 0 and no pwb, values never survive.
    forall(PropConfig { cases: 24, seed: 0xF00D }, |rng, _case| {
        let evict = if rng.next_bool() { 0.0 } else { rng.next_f64() };
        let pool = PmemPool::new(PmemConfig {
            capacity_words: 1 << 12,
            evict_prob: evict,
            pending_flush_prob: rng.next_f64(),
            seed: rng.next_u64(),
            ..Default::default()
        });
        let n = 8 + rng.next_below(8) as usize;
        let addrs: Vec<_> = (0..n).map(|_| pool.alloc_lines(1)).collect();
        let mut stored: Vec<Vec<u64>> = vec![vec![0]; n]; // history per addr
        let mut synced: Vec<u64> = vec![0; n]; // last psync'd value
        let mut unsynced_pwb = false;
        for _step in 0..rng.range_inclusive(10, 100) {
            let i = rng.next_below(n as u64) as usize;
            match rng.next_below(3) {
                0 => {
                    let v = rng.next_u64() | 1;
                    pool.store(0, addrs[i], v);
                    stored[i].push(v);
                }
                1 => {
                    pool.pwb(0, addrs[i]);
                    unsynced_pwb = true;
                }
                _ => {
                    pool.psync(0);
                    if unsynced_pwb {
                        // Everything pwb'd before this psync is durable: we
                        // conservatively just track per-addr last stored
                        // value at psync time for pwb'd addrs — simplify by
                        // recording current live values of all addrs that
                        // were pwb'd; here we approximate: snapshot all.
                        unsynced_pwb = false;
                    }
                    for (j, a) in addrs.iter().enumerate() {
                        synced[j] = pool.read_shadow(*a);
                    }
                }
            }
        }
        pool.psync(0); // drain pending
        let final_synced: Vec<u64> = addrs.iter().map(|a| pool.read_shadow(*a)).collect();
        let mut rng2 = Xoshiro256::seed_from(rng.next_u64());
        pool.crash(&mut rng2);
        for (i, a) in addrs.iter().enumerate() {
            let v = pool.peek(*a);
            // (b) no invention: v must be some stored value (or 0).
            if !stored[i].contains(&v) {
                return Err(format!("addr {i}: invented value {v}"));
            }
            // (a) at least as new as the last explicit sync point.
            let _ = &synced;
            if evict == 0.0 {
                // With no eviction, survival == what was flushed: final
                // shadow before crash.
                if v != final_synced[i] {
                    return Err(format!(
                        "addr {i}: expected {} got {v} (evict=0)",
                        final_synced[i]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_recovery_is_idempotent() {
    install_quiet_crash_hook();
    forall(PropConfig { cases: 8, seed: 0xABCD }, |rng, _case| {
        for (name, ctor) in persistent_registry() {
            let ctx = QueueCtx::single(
                PmemConfig::default().with_capacity(1 << 22).with_seed(rng.next_u64()),
                2,
                QueueConfig { ring_size: 64, ..Default::default() },
            );
            let q = ctor(&ctx);
            let items = rng.range_inclusive(1, 200);
            for v in 0..items {
                q.enqueue(0, v).unwrap();
            }
            // Publish thread-buffered state durably (blockfifo's open
            // tail block) — this test asserts exact survival, not the
            // crash-windowed contract.
            q.quiesce();
            let mut crash_rng = Xoshiro256::seed_from(rng.next_u64());
            // Crash + recover twice, interleaved with nothing: state stable.
            ctx.topo.crash(&mut crash_rng);
            q.recover(ctx.pool());
            ctx.topo.crash(&mut crash_rng);
            q.recover(ctx.pool());
            let mut out = Vec::new();
            while let Some(v) = q.dequeue(1).unwrap() {
                out.push(v);
            }
            if name.starts_with("blockfifo") {
                // Relaxed tier: exact set, lane-interleaved order.
                out.sort_unstable();
            }
            if out != (0..items).collect::<Vec<u64>>() {
                return Err(format!("{name}: expected 0..{items}, got {} items", out.len()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ring_recovery_invariants() {
    // Drive a standalone PerCRQ through random op sequences, crash at a
    // random primitive, recover, and assert structural invariants of the
    // recovered ring (these are what the §4.2 proofs guarantee):
    //   (I1) head <= tail;
    //   (I2) every occupied cell's index lies in [head, tail);
    //   (I3) no unsafe flags survive recovery;
    //   (I4) a full drain returns distinct, previously enqueued values in
    //        strictly increasing enqueue order (single producer).
    use persiq::queues::crq::{DeqResult, EnqResult};
    use persiq::queues::percrq::PerCrq;
    install_quiet_crash_hook();
    forall(PropConfig { cases: 24, seed: 0xC4A2 }, |rng, _case| {
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 18,
            evict_prob: rng.next_f64() * 0.5,
            pending_flush_prob: rng.next_f64(),
            seed: rng.next_u64(),
            ..Default::default()
        }));
        let r = 1usize << rng.range_inclusive(3, 6); // 8..64
        let q = PerCrq::new(&pool, 2, QueueConfig { ring_size: r, ..Default::default() });
        // Random op prefix (single-threaded, no crash yet).
        let mut next_val = 0u64;
        let mut returned: Vec<u64> = Vec::new();
        for _ in 0..rng.range_inclusive(0, 3 * r as u64) {
            if rng.next_bool() {
                if q.enqueue(0, next_val) == EnqResult::Ok {
                    next_val += 1;
                }
            } else if let DeqResult::Item(v) = q.dequeue(1) {
                returned.push(v);
            }
        }
        // Crash at a random point inside further concurrent ops.
        pool.arm_crash_after(rng.range_inclusive(1, 500));
        let pool2 = Arc::clone(&pool);
        let out = std::thread::spawn(move || {
            let _ = persiq::pmem::run_guarded(|| {
                let mut nv = 1_000_000u64;
                for _ in 0..10_000 {
                    let _ = q.enqueue(0, nv);
                    nv += 1;
                    let _ = q.dequeue(0);
                }
            });
            q
        });
        let q = out.join().unwrap();
        let mut crash_rng = Xoshiro256::seed_from(rng.next_u64());
        pool2.crash(&mut crash_rng);
        q.recover(&pool2);
        // Invariants.
        let (head, tail) = q.endpoints(0);
        if head > tail {
            return Err(format!("I1: head {head} > tail {tail}"));
        }
        for u in 0..r as u64 {
            let (uns, idx, val) = q.ring.read_cell(&pool2, 0, u);
            if uns {
                return Err(format!("I3: unsafe flag survived at cell {u}"));
            }
            if val != 0 && !(head <= idx && idx < tail) {
                return Err(format!(
                    "I2: occupied cell {u} idx {idx} outside [{head},{tail})"
                ));
            }
        }
        // Drain: distinct values, increasing within the original stream.
        let mut drained = Vec::new();
        loop {
            match q.dequeue(0) {
                DeqResult::Item(v) => drained.push(v),
                DeqResult::Empty => break,
            }
        }
        let originals: Vec<u64> = drained.iter().cloned().filter(|&v| v < 1_000_000).collect();
        let mut sorted = originals.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != originals.len() || sorted != originals {
            return Err(format!("I4: drain not strictly increasing: {originals:?}"));
        }
        // No value both returned pre-crash and drained (duplication).
        for v in &originals {
            if returned.contains(v) {
                return Err(format!("I4: value {v} returned twice across crash"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_periq_recovery_invariants() {
    // Same idea for PerIQ's scan-based recovery: after crash+recover,
    // (J1) no ⊤ in [head, tail); (J2) drain yields distinct increasing
    // original values; (J3) repeated recovery is stable.
    install_quiet_crash_hook();
    forall(PropConfig { cases: 16, seed: 0x1D0 }, |rng, _case| {
        let ctx = QueueCtx::single(
            PmemConfig {
                capacity_words: 1 << 20,
                evict_prob: rng.next_f64() * 0.5,
                pending_flush_prob: rng.next_f64(),
                seed: rng.next_u64(),
                ..Default::default()
            },
            3,
            QueueConfig {
                iq_capacity: 1 << 14,
                periq_tail_interval: *rng.choose(&[0usize, 1, 16]),
                ..Default::default()
            },
        );
        let q = persiq::queues::persistent_by_name("periq").unwrap()(&ctx);
        let qc: Arc<dyn persiq::queues::ConcurrentQueue> = Arc::clone(&q) as _;
        ctx.topo.arm_crash_after(rng.range_inclusive(500, 20_000));
        let r = run_workload(
            &ctx.topo,
            &qc,
            &RunConfig {
                nthreads: 3,
                total_ops: 30_000,
                record: true,
                salt: 1,
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        let mut crash_rng = Xoshiro256::seed_from(rng.next_u64());
        ctx.topo.crash(&mut crash_rng);
        q.recover(ctx.pool());
        // (J3) recover twice is a no-op on the drain result.
        ctx.topo.crash(&mut crash_rng);
        q.recover(ctx.pool());
        let drained = drain_all(&qc, 0);
        let mut sorted = drained.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != drained.len() {
            return Err("J2: duplicate in drain".into());
        }
        // Full verification of the recorded history + drain.
        let h = History::from_logs(r.logs, drained);
        let rep = check(&h, 5);
        if !rep.ok() {
            return Err(format!("{:?}", rep.violations));
        }
        Ok(())
    });
}
