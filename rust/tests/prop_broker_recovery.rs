//! Property tests for coordinator crash recovery: randomized crash cycles
//! through the broker (classic PerLCRQ and sharded/batched work queues)
//! must reconcile the persistent per-thread SubmitLogs with the audit —
//! no durably submitted job lost, none completed twice.

use std::sync::Arc;

use persiq::coordinator::{run_service, Broker, JobState, ServiceConfig};
use persiq::pmem::crash::{install_quiet_crash_hook, run_guarded};
use persiq::pmem::{PmemConfig, PmemPool};
use persiq::queues::QueueConfig;
use persiq::util::rng::Xoshiro256;
use persiq::verify::proptest::{forall, PropConfig};

fn mk_pool(rng: &mut Xoshiro256, cap: usize) -> Arc<PmemPool> {
    Arc::new(PmemPool::new(PmemConfig {
        capacity_words: cap,
        evict_prob: rng.next_f64() * 0.5,
        pending_flush_prob: rng.next_f64(),
        seed: rng.next_u64(),
        ..Default::default()
    }))
}

#[test]
fn service_crash_cycles_reconcile_for_both_queue_kinds() {
    install_quiet_crash_hook();
    forall(PropConfig { cases: 8, seed: 0x10B5 }, |rng, case| {
        let pool = mk_pool(rng, 1 << 23);
        let nthreads = 4;
        let broker = if case % 2 == 0 {
            Arc::new(Broker::new(&pool, nthreads, 1 << 16, 256))
        } else {
            let qcfg = QueueConfig {
                shards: 1 + rng.next_below(4) as usize,
                batch: *rng.choose(&[1usize, 2, 4]),
                batch_deq: *rng.choose(&[1usize, 2, 4]),
                ring_size: 256,
                ..Default::default()
            };
            Arc::new(Broker::new_sharded(&pool, nthreads, 1 << 16, qcfg).unwrap())
        };
        let cfg = ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 100 + rng.next_below(150) as usize,
            crash_cycles: 1 + rng.next_below(3) as usize,
            crash_steps: 10_000 + rng.next_below(30_000),
            seed: rng.next_u64(),
        };
        let rep = run_service(&pool, &broker, &cfg).map_err(|e| e.to_string())?;
        if rep.done != rep.submitted {
            return Err(format!(
                "case {case}: submitted={} done={} pending={} — job lost or stuck",
                rep.submitted, rep.done, rep.pending_after
            ));
        }
        if rep.pending_after != 0 {
            return Err(format!("case {case}: {} jobs left pending", rep.pending_after));
        }
        Ok(())
    });
}

#[test]
fn forced_crash_mid_submission_never_loses_or_doubles() {
    install_quiet_crash_hook();
    forall(PropConfig { cases: 10, seed: 0xB40C }, |rng, case| {
        let pool = mk_pool(rng, 1 << 22);
        let broker = Arc::new(Broker::new(&pool, 2, 1 << 14, 128));
        let mut crash_rng = Xoshiro256::seed_from(rng.next_u64());

        // Submit under an armed crash countdown: the crash lands inside
        // submit()'s record-write / log-append / enqueue window.
        pool.arm_crash_after(500 + rng.next_below(4_000));
        let target = 200usize;
        let b = Arc::clone(&broker);
        let out = run_guarded(move || {
            for i in 0..target {
                b.submit(0, &[i as u8, (i >> 8) as u8]).unwrap();
            }
        });
        let crashed = out.crashed();
        pool.crash(&mut crash_rng);
        broker.recover();

        // Audit invariant: every durably logged job is PENDING, DONE or
        // (only for the submission interrupted mid-flight) unwritten.
        let audit = broker.audit(0);
        if audit.unwritten > 1 {
            return Err(format!(
                "case {case} (crashed={crashed}): {} unwritten records — only the \
                 in-flight submission may lack a durable record ({audit:?})",
                audit.unwritten
            ));
        }
        if audit.done != 0 {
            return Err(format!("case {case}: jobs done before any take ({audit:?})"));
        }

        // Drain and complete everything; each delivery must win its CAS
        // exactly once and every logged-and-written job must be delivered.
        let mut completions = 0usize;
        while let Some((jid, _payload)) = broker.take(1).map_err(|e| e.to_string())? {
            if !broker.complete(1, jid).map_err(|e| e.to_string())? {
                return Err(format!("case {case}: double completion of {jid:?}"));
            }
            if broker.state(0, jid) != JobState::Done {
                return Err(format!("case {case}: completed job not durably DONE"));
            }
            completions += 1;
        }
        let final_audit = broker.audit(0);
        if final_audit.pending != 0 {
            return Err(format!(
                "case {case}: {} durably submitted jobs never delivered ({final_audit:?})",
                final_audit.pending
            ));
        }
        if completions != final_audit.done {
            return Err(format!(
                "case {case}: {completions} completions vs {} DONE records",
                final_audit.done
            ));
        }
        Ok(())
    });
}
