//! Property tests for coordinator crash recovery: randomized crash cycles
//! through the broker (classic PerLCRQ and sharded/batched work queues)
//! must reconcile the persistent per-thread SubmitLogs with the audit —
//! no durably submitted job lost, none completed twice.

use std::sync::Arc;

use persiq::coordinator::{run_service, Broker, JobState, ServiceConfig};
use persiq::pmem::crash::{install_quiet_crash_hook, run_guarded};
use persiq::pmem::{PlacementPolicy, PmemConfig, Topology};
use persiq::queues::QueueConfig;
use persiq::util::rng::Xoshiro256;
use persiq::verify::proptest::{forall, PropConfig};

fn mk_topo(rng: &mut Xoshiro256, cap: usize, pools: usize) -> Topology {
    Topology::new(
        PmemConfig {
            capacity_words: cap,
            evict_prob: rng.next_f64() * 0.5,
            pending_flush_prob: rng.next_f64(),
            seed: rng.next_u64(),
            ..Default::default()
        },
        pools,
    )
}

#[test]
fn service_crash_cycles_reconcile_for_both_queue_kinds() {
    install_quiet_crash_hook();
    forall(PropConfig { cases: 8, seed: 0x10B5 }, |rng, case| {
        let nthreads = 4;
        let (topo, broker) = if case % 2 == 0 {
            let topo = mk_topo(rng, 1 << 23, 1);
            let b = Arc::new(Broker::new_on(&topo, nthreads, 1 << 16, 256));
            (topo, b)
        } else {
            // Sharded work queue, randomly on a 1- or 2-pool topology
            // with a random placement policy.
            let pools = *rng.choose(&[1usize, 2]);
            let topo = mk_topo(rng, 1 << 23, pools);
            let placement = if pools == 1 {
                PlacementPolicy::Interleave
            } else {
                rng.choose(&[
                    PlacementPolicy::Interleave,
                    PlacementPolicy::Colocate,
                    PlacementPolicy::Pinned(vec![0, 1]),
                ])
                .clone()
            };
            let qcfg = QueueConfig {
                shards: 1 + rng.next_below(4) as usize,
                batch: *rng.choose(&[1usize, 2, 4]),
                batch_deq: *rng.choose(&[1usize, 2, 4]),
                ring_size: 256,
                placement,
                ..Default::default()
            };
            let b = Arc::new(Broker::new_sharded(&topo, nthreads, 1 << 16, qcfg).unwrap());
            (topo, b)
        };
        let cfg = ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 100 + rng.next_below(150) as usize,
            crash_cycles: 1 + rng.next_below(3) as usize,
            crash_steps: 10_000 + rng.next_below(30_000),
            seed: rng.next_u64(),
            ..Default::default()
        };
        let rep = run_service(&topo, &broker, &cfg).map_err(|e| e.to_string())?;
        if rep.done != rep.submitted {
            return Err(format!(
                "case {case}: submitted={} done={} pending={} — job lost or stuck",
                rep.submitted, rep.done, rep.pending_after
            ));
        }
        if rep.pending_after != 0 {
            return Err(format!("case {case}: {} jobs left pending", rep.pending_after));
        }
        let rec = broker.reconcile_report(0);
        if rec.mismatches() != 0 {
            return Err(format!("case {case}: reconciliation mismatches {rec:?}"));
        }
        Ok(())
    });
}

#[test]
fn forced_crash_mid_submission_never_loses_or_doubles() {
    install_quiet_crash_hook();
    forall(PropConfig { cases: 10, seed: 0xB40C }, |rng, case| {
        // Alternate single- and two-pool topologies: the submit path is
        // socket-local either way, and the crash window sits between the
        // home pool's log append and the queue enqueue.
        let topo = mk_topo(rng, 1 << 22, 1 + (case % 2) as usize);
        let broker = Arc::new(Broker::new_on(&topo, 2, 1 << 14, 128));
        let mut crash_rng = Xoshiro256::seed_from(rng.next_u64());

        // Submit under an armed crash countdown: the crash lands inside
        // submit()'s record-write / log-append / enqueue window.
        topo.arm_crash_after(500 + rng.next_below(4_000));
        let target = 200usize;
        let b = Arc::clone(&broker);
        let out = run_guarded(move || {
            for i in 0..target {
                b.submit(0, &[i as u8, (i >> 8) as u8]).unwrap();
            }
        });
        let crashed = out.crashed();
        topo.crash(&mut crash_rng);
        broker.recover();

        // Audit invariant: every durably logged job is PENDING, DONE or
        // (only for the submission interrupted mid-flight) unwritten.
        let audit = broker.audit(0);
        if audit.unwritten > 1 {
            return Err(format!(
                "case {case} (crashed={crashed}): {} unwritten records — only the \
                 in-flight submission may lack a durable record ({audit:?})",
                audit.unwritten
            ));
        }
        if audit.done != 0 {
            return Err(format!("case {case}: jobs done before any take ({audit:?})"));
        }

        // Drain and complete everything; each delivery must win its CAS
        // exactly once and every logged-and-written job must be delivered.
        let mut completions = 0usize;
        while let Some((jid, _payload)) = broker.take(1).map_err(|e| e.to_string())? {
            if !broker.complete(1, jid).map_err(|e| e.to_string())? {
                return Err(format!("case {case}: double completion of {jid:?}"));
            }
            if broker.state(0, jid) != JobState::Done {
                return Err(format!("case {case}: completed job not durably DONE"));
            }
            completions += 1;
        }
        let final_audit = broker.audit(0);
        if final_audit.pending != 0 {
            return Err(format!(
                "case {case}: {} durably submitted jobs never delivered ({final_audit:?})",
                final_audit.pending
            ));
        }
        if completions != final_audit.done {
            return Err(format!(
                "case {case}: {completions} completions vs {} DONE records",
                final_audit.done
            ));
        }
        Ok(())
    });
}
