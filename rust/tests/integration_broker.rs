//! End-to-end broker integration: the coordinator service across crash
//! cycles with full audits, worker-death leases, and the async serve
//! path.

use std::sync::Arc;

use persiq::coordinator::{run_service, Broker, JobState, ServiceConfig};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::{PmemConfig, Topology};
use persiq::queues::asyncq::AsyncCfg;
use persiq::queues::QueueConfig;

fn mk(cap_words: usize) -> (Topology, Arc<Broker>) {
    mk_topo(cap_words, 1)
}

fn mk_topo(cap_words: usize, pools: usize) -> (Topology, Arc<Broker>) {
    let topo = Topology::new(
        PmemConfig {
            capacity_words: cap_words,
            evict_prob: 0.25,
            pending_flush_prob: 0.5,
            seed: 77,
            ..Default::default()
        },
        pools,
    );
    let broker = Arc::new(Broker::new_on(&topo, 8, 1 << 16, 1 << 10));
    (topo, broker)
}

#[test]
fn service_end_to_end_no_crash() {
    let (topo, broker) = mk(1 << 22);
    let rep = run_service(
        &topo,
        &broker,
        &ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 400,
            crash_cycles: 0,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.submitted, 800);
    assert_eq!(rep.done, 800);
    assert_eq!(rep.pending_after, 0);
}

#[test]
fn service_with_crashes_exactly_once() {
    install_quiet_crash_hook();
    let (topo, broker) = mk(1 << 23);
    let rep = run_service(
        &topo,
        &broker,
        &ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 400,
            crash_cycles: 3,
            crash_steps: 40_000,
            seed: 3,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.crashes, 3);
    assert_eq!(rep.done, rep.submitted, "{rep:?}");
    assert_eq!(rep.pending_after, 0);
}

#[test]
fn lease_redelivers_after_worker_death_without_crash() {
    // The lease satellite end to end: a worker takes jobs and dies
    // silently (its thread just stops — no crash, no recovery). The
    // expired leases must redeliver exactly those jobs; everything
    // completes exactly once across the worker generations.
    let (_topo, broker) = mk(1 << 22);
    broker.set_lease_ms(5);
    let total = 30usize;
    for i in 0..total {
        broker.submit(0, format!("job-{i}").as_bytes()).unwrap();
    }
    // Worker generation 1 (tid 1): takes 10 jobs, completes 4, then dies
    // holding 6 in flight.
    let b2 = Arc::clone(&broker);
    std::thread::spawn(move || {
        let mut taken = Vec::new();
        for _ in 0..10 {
            taken.push(b2.take(1).unwrap().expect("jobs available").0);
        }
        for jid in taken.into_iter().take(4) {
            assert!(b2.complete(1, jid).unwrap());
        }
        // ...and the worker vanishes with 6 unacked jobs.
    })
    .join()
    .unwrap();
    assert_eq!(broker.leases_outstanding(), 6);
    std::thread::sleep(std::time::Duration::from_millis(10));
    assert_eq!(broker.reap_expired(2), 6, "all abandoned jobs must requeue");
    // Worker generation 2 (tid 2) finishes everything.
    let mut done = 4usize;
    while let Some((jid, _)) = broker.take(2).unwrap() {
        if broker.complete(2, jid).unwrap() {
            done += 1;
        }
    }
    assert_eq!(done, total, "every job completed exactly once across generations");
    let audit = broker.audit(0);
    assert_eq!(audit.done, total);
    assert_eq!(audit.pending, 0);
    assert_eq!(broker.leases_outstanding(), 0);
}

#[test]
fn async_service_with_crashes_and_leases_exactly_once() {
    // The async serve path under crash cycles, with leasing on: the
    // combined stack (submit_async / take_async / ack_async + lease
    // reaping + recovery reconciliation) must still complete every
    // durably submitted job exactly once.
    install_quiet_crash_hook();
    let topo = Topology::new(
        PmemConfig {
            capacity_words: 1 << 23,
            evict_prob: 0.25,
            pending_flush_prob: 0.5,
            seed: 78,
            ..Default::default()
        },
        2,
    );
    let acfg = AsyncCfg { flush_us: 100, depth: 8, flushers: 2 };
    let broker = Arc::new(
        Broker::new_sharded(
            &topo,
            2 + 2 + acfg.flushers,
            1 << 16,
            QueueConfig {
                shards: 4,
                batch: 4,
                batch_deq: 4,
                ring_size: 1 << 10,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let rep = run_service(
        &topo,
        &broker,
        &ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 250,
            crash_cycles: 3,
            crash_steps: 35_000,
            seed: 9,
            use_async: true,
            acfg,
            lease_ms: 50,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.crashes, 3);
    assert_eq!(rep.done, rep.submitted, "{rep:?}");
    assert_eq!(rep.pending_after, 0);
    assert_eq!(broker.reconcile_report(0).mismatches(), 0);
}

#[test]
fn lease_starts_at_async_resolution_not_resolve_take() {
    // The lease-at-resolution satellite: a worker awaits `take_async` to
    // RESOLUTION (consumption durable) and dies before `resolve_take`.
    // Pre-fix this stranded the job (durably consumed, unleased,
    // PENDING) until a crash recovery; now the combiner starts the lease
    // at the durability point, so `reap_expired` redelivers it.
    let topo = Topology::new(
        PmemConfig {
            capacity_words: 1 << 21,
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 5,
            ..Default::default()
        },
        1,
    );
    let broker = Arc::new(
        Broker::new_sharded(
            &topo,
            4 + 1,
            1 << 12,
            QueueConfig { shards: 2, batch: 2, batch_deq: 2, ring_size: 256, ..Default::default() },
        )
        .unwrap(),
    );
    broker.set_lease_ms(1);
    let aq = broker.async_layer(AsyncCfg { flush_us: 100, depth: 4, flushers: 1 }).unwrap();
    let fl = aq.spawn_flusher(4);
    let (_id, f) = broker.submit_async(0, b"orphan", &aq).unwrap();
    f.wait().unwrap();
    // "Worker": awaits the take future, then dies silently — NO
    // resolve_take, no ack.
    let handle = broker.take_async(&aq).wait().unwrap().expect("durably taken");
    assert_eq!(
        broker.leases_outstanding(),
        1,
        "the lease must exist the moment the take future resolves"
    );
    std::thread::sleep(std::time::Duration::from_millis(5));
    assert_eq!(broker.reap_expired(1), 1, "expired at-resolution lease must redeliver");
    let (jid, payload) = broker.take(1).unwrap().expect("redelivered job");
    assert_eq!(&payload, b"orphan");
    assert!(broker.complete(1, jid).unwrap());
    fl.stop();
    assert_eq!(broker.audit(0).done, 1);
    assert_eq!(broker.reap_expired(1), 0, "completed job must not be reaped again");
    let _ = handle; // the original taker never resolved it — by design
}

#[test]
fn payload_integrity_across_crash() {
    install_quiet_crash_hook();
    let (topo, broker) = mk(1 << 22);
    let payloads: Vec<Vec<u8>> =
        (0..50u8).map(|i| format!("payload-{i:03}-{}", "x".repeat(i as usize % 20)).into_bytes()).collect();
    let mut ids = Vec::new();
    for p in &payloads {
        ids.push(broker.submit(0, p).unwrap());
    }
    let mut rng = persiq::util::rng::Xoshiro256::seed_from(5);
    topo.crash(&mut rng);
    broker.recover();
    for (i, expect) in payloads.iter().enumerate() {
        let (jid, got) = broker.take(1).unwrap().expect("job missing");
        assert_eq!(&got, expect, "payload {i} corrupted");
        assert!(broker.complete(1, jid).unwrap());
        assert_eq!(broker.state(0, ids[i]), JobState::Done);
    }
    assert!(broker.take(1).unwrap().is_none());
}

#[test]
fn payload_integrity_across_crash_on_two_pools() {
    // Records submitted from both home sockets survive a coordinated
    // crash with their payloads intact; audits walk both pools' logs.
    install_quiet_crash_hook();
    let (topo, broker) = mk_topo(1 << 22, 2);
    let mut expected = Vec::new();
    for i in 0..40u8 {
        let tid = (i % 2) as usize; // alternate home pools
        let payload = format!("pool{}-job-{i:03}", tid).into_bytes();
        broker.submit(tid, &payload).unwrap();
        expected.push(payload);
    }
    let mut rng = persiq::util::rng::Xoshiro256::seed_from(6);
    topo.crash(&mut rng);
    broker.recover();
    let mut got = Vec::new();
    while let Some((jid, payload)) = broker.take(2).unwrap() {
        assert!(broker.complete(2, jid).unwrap());
        got.push(payload);
    }
    got.sort_unstable();
    expected.sort_unstable();
    assert_eq!(got, expected, "payloads must survive the coordinated 2-pool crash");
    let audit = broker.audit(0);
    assert_eq!(audit.submitted, 40);
    assert_eq!(audit.done, 40);
    assert_eq!(broker.reconcile_report(0).mismatches(), 0);
}
