//! End-to-end broker integration: the coordinator service across crash
//! cycles with full audits.

use std::sync::Arc;

use persiq::coordinator::{run_service, Broker, JobState, ServiceConfig};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::{PmemConfig, Topology};

fn mk(cap_words: usize) -> (Topology, Arc<Broker>) {
    mk_topo(cap_words, 1)
}

fn mk_topo(cap_words: usize, pools: usize) -> (Topology, Arc<Broker>) {
    let topo = Topology::new(
        PmemConfig {
            capacity_words: cap_words,
            evict_prob: 0.25,
            pending_flush_prob: 0.5,
            seed: 77,
            ..Default::default()
        },
        pools,
    );
    let broker = Arc::new(Broker::new_on(&topo, 8, 1 << 16, 1 << 10));
    (topo, broker)
}

#[test]
fn service_end_to_end_no_crash() {
    let (topo, broker) = mk(1 << 22);
    let rep = run_service(
        &topo,
        &broker,
        &ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 400,
            crash_cycles: 0,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.submitted, 800);
    assert_eq!(rep.done, 800);
    assert_eq!(rep.pending_after, 0);
}

#[test]
fn service_with_crashes_exactly_once() {
    install_quiet_crash_hook();
    let (topo, broker) = mk(1 << 23);
    let rep = run_service(
        &topo,
        &broker,
        &ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 400,
            crash_cycles: 3,
            crash_steps: 40_000,
            seed: 3,
        },
    )
    .unwrap();
    assert_eq!(rep.crashes, 3);
    assert_eq!(rep.done, rep.submitted, "{rep:?}");
    assert_eq!(rep.pending_after, 0);
}

#[test]
fn payload_integrity_across_crash() {
    install_quiet_crash_hook();
    let (topo, broker) = mk(1 << 22);
    let payloads: Vec<Vec<u8>> =
        (0..50u8).map(|i| format!("payload-{i:03}-{}", "x".repeat(i as usize % 20)).into_bytes()).collect();
    let mut ids = Vec::new();
    for p in &payloads {
        ids.push(broker.submit(0, p).unwrap());
    }
    let mut rng = persiq::util::rng::Xoshiro256::seed_from(5);
    topo.crash(&mut rng);
    broker.recover();
    for (i, expect) in payloads.iter().enumerate() {
        let (jid, got) = broker.take(1).unwrap().expect("job missing");
        assert_eq!(&got, expect, "payload {i} corrupted");
        assert!(broker.complete(1, jid).unwrap());
        assert_eq!(broker.state(0, ids[i]), JobState::Done);
    }
    assert!(broker.take(1).unwrap().is_none());
}

#[test]
fn payload_integrity_across_crash_on_two_pools() {
    // Records submitted from both home sockets survive a coordinated
    // crash with their payloads intact; audits walk both pools' logs.
    install_quiet_crash_hook();
    let (topo, broker) = mk_topo(1 << 22, 2);
    let mut expected = Vec::new();
    for i in 0..40u8 {
        let tid = (i % 2) as usize; // alternate home pools
        let payload = format!("pool{}-job-{i:03}", tid).into_bytes();
        broker.submit(tid, &payload).unwrap();
        expected.push(payload);
    }
    let mut rng = persiq::util::rng::Xoshiro256::seed_from(6);
    topo.crash(&mut rng);
    broker.recover();
    let mut got = Vec::new();
    while let Some((jid, payload)) = broker.take(2).unwrap() {
        assert!(broker.complete(2, jid).unwrap());
        got.push(payload);
    }
    got.sort_unstable();
    expected.sort_unstable();
    assert_eq!(got, expected, "payloads must survive the coordinated 2-pool crash");
    let audit = broker.audit(0);
    assert_eq!(audit.submitted, 40);
    assert_eq!(audit.done, 40);
    assert_eq!(broker.reconcile_report(0).mismatches(), 0);
}
