//! End-to-end broker integration: the coordinator service across crash
//! cycles with full audits.

use std::sync::Arc;

use persiq::coordinator::{run_service, Broker, JobState, ServiceConfig};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::{PmemConfig, PmemPool};

fn mk(cap_words: usize) -> (Arc<PmemPool>, Arc<Broker>) {
    let pool = Arc::new(PmemPool::new(PmemConfig {
        capacity_words: cap_words,
        evict_prob: 0.25,
        pending_flush_prob: 0.5,
        seed: 77,
        ..Default::default()
    }));
    let broker = Arc::new(Broker::new(&pool, 8, 1 << 16, 1 << 10));
    (pool, broker)
}

#[test]
fn service_end_to_end_no_crash() {
    let (pool, broker) = mk(1 << 22);
    let rep = run_service(
        &pool,
        &broker,
        &ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 400,
            crash_cycles: 0,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.submitted, 800);
    assert_eq!(rep.done, 800);
    assert_eq!(rep.pending_after, 0);
}

#[test]
fn service_with_crashes_exactly_once() {
    install_quiet_crash_hook();
    let (pool, broker) = mk(1 << 23);
    let rep = run_service(
        &pool,
        &broker,
        &ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 400,
            crash_cycles: 3,
            crash_steps: 40_000,
            seed: 3,
        },
    )
    .unwrap();
    assert_eq!(rep.crashes, 3);
    assert_eq!(rep.done, rep.submitted, "{rep:?}");
    assert_eq!(rep.pending_after, 0);
}

#[test]
fn payload_integrity_across_crash() {
    install_quiet_crash_hook();
    let (pool, broker) = mk(1 << 22);
    let payloads: Vec<Vec<u8>> =
        (0..50u8).map(|i| format!("payload-{i:03}-{}", "x".repeat(i as usize % 20)).into_bytes()).collect();
    let mut ids = Vec::new();
    for p in &payloads {
        ids.push(broker.submit(0, p).unwrap());
    }
    let mut rng = persiq::util::rng::Xoshiro256::seed_from(5);
    pool.crash(&mut rng);
    broker.recover();
    for (i, expect) in payloads.iter().enumerate() {
        let (jid, got) = broker.take(1).unwrap().expect("job missing");
        assert_eq!(&got, expect, "payload {i} corrupted");
        assert!(broker.complete(1, jid).unwrap());
        assert_eq!(broker.state(0, ids[i]), JobState::Done);
    }
    assert!(broker.take(1).unwrap().is_none());
}
