//! The verifier must actually catch broken queues: inject defects through
//! a wrapper and assert detection (meta-testing the checker).

use std::sync::Arc;
use std::sync::Mutex;

use persiq::harness::runner::{drain_all, run_workload, RunConfig};
use persiq::pmem::{PmemConfig, Topology};
use persiq::queues::{by_name, ConcurrentQueue, QueueConfig, QueueCtx, QueueError};
use persiq::verify::{check, History, Violation};

/// A queue wrapper that duplicates every Nth dequeued value.
struct DupInjector {
    inner: Arc<dyn ConcurrentQueue>,
    stash: Mutex<Option<u64>>,
    period: u64,
    count: Mutex<u64>,
}

impl ConcurrentQueue for DupInjector {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        self.inner.enqueue(tid, item)
    }
    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        if let Some(v) = self.stash.lock().unwrap().take() {
            return Ok(Some(v)); // duplicate!
        }
        let r = self.inner.dequeue(tid)?;
        if let Some(v) = r {
            let mut c = self.count.lock().unwrap();
            *c += 1;
            if *c % self.period == 0 {
                *self.stash.lock().unwrap() = Some(v);
            }
        }
        Ok(r)
    }
    fn name(&self) -> &'static str {
        "dup-injector"
    }
}

/// A queue wrapper that silently drops every Nth enqueue.
struct LossInjector {
    inner: Arc<dyn ConcurrentQueue>,
    period: u64,
    count: Mutex<u64>,
}

impl ConcurrentQueue for LossInjector {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        let mut c = self.count.lock().unwrap();
        *c += 1;
        if *c % self.period == 0 {
            return Ok(()); // pretend success, drop the item
        }
        drop(c);
        self.inner.enqueue(tid, item)
    }
    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        self.inner.dequeue(tid)
    }
    fn name(&self) -> &'static str {
        "loss-injector"
    }
}

/// A "queue" that reorders: it's a LIFO stack (violates FIFO).
struct LifoQueue {
    stack: Mutex<Vec<u64>>,
}

impl ConcurrentQueue for LifoQueue {
    fn enqueue(&self, _tid: usize, item: u64) -> Result<(), QueueError> {
        self.stack.lock().unwrap().push(item);
        Ok(())
    }
    fn dequeue(&self, _tid: usize) -> Result<Option<u64>, QueueError> {
        Ok(self.stack.lock().unwrap().pop())
    }
    fn name(&self) -> &'static str {
        "lifo"
    }
}

fn ctx() -> QueueCtx {
    QueueCtx::single(
        PmemConfig::default().with_capacity(1 << 21),
        2,
        QueueConfig::default(),
    )
}

fn run_and_check(q: Arc<dyn ConcurrentQueue>, topo: &Topology) -> Vec<Violation> {
    let r = run_workload(
        topo,
        &q,
        &RunConfig { nthreads: 2, total_ops: 4_000, record: true, ..Default::default() },
    );
    let drained = drain_all(&q, 0);
    let h = History::from_logs(r.logs, drained);
    check(&h, 20).violations
}

#[test]
fn detects_injected_duplicates() {
    let c = ctx();
    let inner = by_name("perlcrq").unwrap()(&c);
    let q: Arc<dyn ConcurrentQueue> = Arc::new(DupInjector {
        inner,
        stash: Mutex::new(None),
        period: 50,
        count: Mutex::new(0),
    });
    let v = run_and_check(q, &c.topo);
    assert!(
        v.iter().any(|x| matches!(x, Violation::Duplicate { .. })),
        "checker must flag duplicates, got {v:?}"
    );
}

#[test]
fn detects_injected_loss() {
    let c = ctx();
    let inner = by_name("perlcrq").unwrap()(&c);
    let q: Arc<dyn ConcurrentQueue> =
        Arc::new(LossInjector { inner, period: 100, count: Mutex::new(0) });
    let v = run_and_check(q, &c.topo);
    assert!(
        v.iter().any(|x| matches!(x, Violation::Lost { .. })),
        "checker must flag losses, got {v:?}"
    );
}

#[test]
fn detects_lifo_order_violation() {
    // Two phases (fill, then drain) so strictly-ordered enqueue pairs get
    // dequeued in reversed order. Seq stamps are process-global, so logs
    // from both runs merge into one totally ordered history.
    use persiq::harness::Workload;
    let c = ctx();
    let q: Arc<dyn ConcurrentQueue> = Arc::new(LifoQueue { stack: Mutex::new(Vec::new()) });
    let r1 = run_workload(
        &c.topo,
        &q,
        &RunConfig {
            nthreads: 1,
            total_ops: 100,
            workload: Workload::EnqOnly,
            record: true,
            ..Default::default()
        },
    );
    let r2 = run_workload(
        &c.topo,
        &q,
        &RunConfig {
            nthreads: 1,
            total_ops: 100,
            workload: Workload::DeqHeavy,
            record: true,
            salt: 2,
            ..Default::default()
        },
    );
    let mut logs = r1.logs;
    logs.extend(r2.logs);
    let drained = drain_all(&q, 0);
    let h = History::from_logs(logs, drained);
    let v = check(&h, 20).violations;
    assert!(
        v.iter().any(|x| matches!(x, Violation::FifoInversion { .. })),
        "checker must flag FIFO inversions on a LIFO, got {v:?}"
    );
}

#[test]
fn clean_queue_has_no_violations() {
    let c = ctx();
    let q = by_name("perlcrq").unwrap()(&c);
    let v = run_and_check(q, &c.topo);
    assert!(v.is_empty(), "{v:?}");
}
