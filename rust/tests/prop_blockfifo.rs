//! Crash-swept property tests for the block-granular tier (blockfifo):
//! randomized lane/block/fault-rate configurations with crashes landing at
//! arbitrary pmem primitives must never lose a durably-claimed block and
//! never redeliver outside the checker-gated allowances, and a
//! single-primitive crash sweep across the enqueue path (between the
//! block-claim FAI, the entry stores, the seal's header store, and inside
//! its pwb/psync train) must never invent, duplicate, or over-lose.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use persiq::harness::runner::{drain_all, run_workload, RunConfig};
use persiq::harness::Workload;
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::{run_guarded, PmemConfig};
use persiq::queues::{persistent_by_name, QueueConfig, QueueCtx};
use persiq::util::rng::Xoshiro256;
use persiq::verify::proptest::{forall, PropConfig};
use persiq::verify::{check_with, options_for, History};

#[test]
fn prop_blockfifo_durable_blocks_survive_random_crashes() {
    install_quiet_crash_hook();
    forall(PropConfig { cases: 8, seed: 0xB10C }, |rng, _case| {
        let nthreads = 2 + rng.next_below(3) as usize; // 2..4
        let shards = *rng.choose(&[1usize, 2, 4]);
        let block = *rng.choose(&[1usize, 4, 16, 64]);
        let cycles = 1 + rng.next_below(3); // 1..3
        let name = *rng.choose(&["blockfifo", "blockfifo-multi"]);
        // Blocks are never recycled: size the lanes (power of two, per
        // validate()) so shards * nblocks * block covers the whole
        // multi-cycle workload with headroom.
        let nblocks = (1usize << 17) / block / shards;
        let ctx = QueueCtx::single(
            PmemConfig {
                capacity_words: 1 << 23,
                evict_prob: rng.next_f64() * 0.5,
                pending_flush_prob: rng.next_f64(),
                seed: rng.next_u64(),
                ..Default::default()
            },
            nthreads,
            QueueConfig {
                shards,
                block,
                ring_size: nblocks,
                dchoice: 1 + rng.next_below(4) as usize,
                ..Default::default()
            },
        );
        let q = persistent_by_name(name).unwrap()(&ctx);
        let qc: Arc<dyn persiq::queues::ConcurrentQueue> = Arc::clone(&q) as _;
        let mut crash_rng = Xoshiro256::seed_from(rng.next_u64());
        let mut logs = Vec::new();
        for cycle in 0..cycles {
            ctx.topo.arm_crash_after(3_000 + rng.next_below(25_000));
            let r = run_workload(
                &ctx.topo,
                &qc,
                &RunConfig {
                    nthreads,
                    total_ops: 30_000,
                    workload: *rng.choose(&[Workload::Pairs, Workload::Random5050]),
                    record: true,
                    salt: cycle + 1,
                    seed: rng.next_u64(),
                    ..Default::default()
                },
            );
            logs.extend(r.logs);
            ctx.topo.crash(&mut crash_rng);
            q.recover(ctx.pool());
        }
        let drained = drain_all(&qc, 0);
        let h = History::from_logs(logs, drained);
        // The same policy the CLI applies: loss gated to unsealed tails
        // (block - 1 per producer per crashed epoch), redelivery gated to
        // rolled-back draining blocks (block per consumer per crashed
        // epoch), EMPTY checking off (open blocks are invisible).
        let opts = options_for(name, nthreads, &ctx.cfg, cycles);
        let rep = check_with(&h, &opts);
        if !rep.ok() {
            return Err(format!(
                "{name} shards={shards} block={block}: {:?} (max_overtakes={})",
                rep.violations, rep.max_overtakes
            ));
        }
        // The allowance is a hard bound, not a soft hint: a DRAINING
        // rollback redelivers at most one block per consumer per crash.
        let cap = block * nthreads * cycles as usize;
        if rep.absorbed_redelivered > cap {
            return Err(format!(
                "{name}: absorbed {} redeliveries, contract caps at {cap}",
                rep.absorbed_redelivered
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_blockfifo_crash_sweep_over_enqueue_path_is_exact() {
    // Land the crash at every successive primitive of a single-producer
    // enqueue stream. Whatever the cut point — mid-fill, between the seal's
    // header store and its pwbs, inside the psync train — recovery must
    // deliver a distinct subset of the returned values, losing at most
    // block - 1 of them, all from the final (unsealed or torn) block.
    install_quiet_crash_hook();
    forall(PropConfig { cases: 48, seed: 0x5EA1 }, |rng, case| {
        let block = *rng.choose(&[1usize, 2, 8, 64]);
        let ctx = QueueCtx::single(
            PmemConfig {
                capacity_words: 1 << 18,
                evict_prob: rng.next_f64() * 0.5,
                pending_flush_prob: rng.next_f64(),
                seed: rng.next_u64(),
                ..Default::default()
            },
            1,
            QueueConfig { shards: 2, block, ring_size: 256, ..Default::default() },
        );
        let q = persistent_by_name("blockfifo").unwrap()(&ctx);
        // Sweep: case index picks the primitive; jitter widens coverage.
        ctx.topo.arm_crash_after(1 + case as u64 * 3 + rng.next_below(3));
        let done = AtomicU64::new(0);
        // Crashed or completed — both cut points are valid cases.
        let _ = run_guarded(|| {
            for v in 0..1_000u64 {
                q.enqueue(0, v).unwrap();
                done.store(v + 1, Ordering::Relaxed);
            }
        });
        let done = done.load(Ordering::Relaxed);
        let mut crash_rng = Xoshiro256::seed_from(rng.next_u64());
        ctx.topo.crash(&mut crash_rng);
        q.recover(ctx.pool());
        let mut out = Vec::new();
        while let Some(v) = q.dequeue(0).unwrap() {
            out.push(v);
        }
        // No duplication, no invention: a distinct subset of the values
        // whose enqueue at least started (`done` returned; `done + 1`-th
        // may have been cut mid-flight after its store).
        let mut sorted = out.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != out.len() {
            return Err(format!("block={block}: duplicate delivery in {out:?}"));
        }
        if sorted.iter().any(|&v| v > done) {
            return Err(format!("block={block}: invented value beyond {done}"));
        }
        // Bounded loss, confined to the last block: an enqueue that
        // triggers a seal only returns after the psync completes, so every
        // earlier block is fully durable and at most the final block's
        // block - 1 returned entries can go missing (its B-th filler is
        // the in-flight op, not a returned one).
        let missing: Vec<u64> = (0..done).filter(|v| !sorted.contains(v)).collect();
        if missing.len() >= block {
            return Err(format!(
                "block={block}: lost {} returned values (cap {})",
                missing.len(),
                block - 1
            ));
        }
        if let Some(&m) = missing.first() {
            if m + (block as u64) <= done {
                return Err(format!(
                    "block={block}: lost value {m} outside the final block (done={done})"
                ));
            }
        }
        Ok(())
    });
}
