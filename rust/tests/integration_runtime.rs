//! PJRT runtime integration: the AOT-compiled JAX/Pallas metrics artifact
//! must load, execute, and agree with the pure-Rust fallback (the L1/L2
//! correctness signal crossing the language boundary).
//!
//! Skips gracefully (with a loud message) when `artifacts/` has not been
//! built — run `make artifacts` first.

use persiq::runtime::engine::{default_artifact_dir, Engine, METRICS_SAMPLES};
use persiq::runtime::{fallback, MetricsEngine};

fn engine() -> Option<Engine> {
    let dir = default_artifact_dir()?;
    Some(Engine::load(&dir).expect("artifact load failed"))
}

macro_rules! need_artifacts {
    () => {
        match engine() {
            Some(e) => e,
            None => {
                eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
                return;
            }
        }
    };
}

#[test]
fn pjrt_metrics_match_fallback() {
    let e = need_artifacts!();
    let samples: Vec<f64> = (0..5000).map(|i| 100.0 + (i % 997) as f64).collect();
    let (stats, hist) = e.metrics(&samples).unwrap();
    let (fstats, fhist) = fallback::metrics(&samples);
    assert_eq!(stats[0], fstats[0], "count");
    for (i, name) in
        [(1, "mean"), (2, "std"), (3, "min"), (4, "max"), (5, "p50"), (6, "p95"), (7, "p99")]
    {
        let (a, b) = (stats[i], fstats[i]);
        let tol = (b.abs() * 1e-3).max(1e-2);
        assert!((a - b).abs() <= tol, "{name}: pjrt={a} fallback={b}");
    }
    assert_eq!(hist.len(), fhist.len());
    let (sa, sb): (f64, f64) = (hist.iter().sum(), fhist.iter().sum());
    assert_eq!(sa, sb, "histogram mass");
}

#[test]
fn pjrt_fit_matches_fallback() {
    let e = need_artifacts!();
    let ns: Vec<f64> = (1..=12).map(|i| i as f64).collect();
    let t: Vec<f64> = ns.iter().map(|&n| n / (1.5 + 0.08 * n)).collect();
    let got = e.fit(&ns, &t).unwrap();
    let want = fallback::fit(&ns, &t);
    for i in 0..3 {
        assert!(
            (got[i] - want[i]).abs() < 1e-2 * want[i].abs().max(1.0),
            "fit[{i}]: pjrt={} fallback={}",
            got[i],
            want[i]
        );
    }
    assert!((got[2] - 12.5).abs() < 0.1, "plateau should be 1/0.08");
}

#[test]
fn pjrt_handles_downsampling() {
    let e = need_artifacts!();
    // More samples than the artifact's fixed shape: deterministic stride
    // downsample must keep distribution shape.
    let samples: Vec<f64> = (0..3 * METRICS_SAMPLES).map(|i| (i % 1000) as f64).collect();
    let (stats, _) = e.metrics(&samples).unwrap();
    assert_eq!(stats[0] as usize, METRICS_SAMPLES);
    assert!((stats[1] - 499.5).abs() < 25.0, "mean ~499.5, got {}", stats[1]);
}

#[test]
fn pjrt_empty_and_tiny_inputs() {
    let e = need_artifacts!();
    let (stats, hist) = e.metrics(&[]).unwrap();
    assert_eq!(stats[0], 0.0);
    assert_eq!(hist.iter().sum::<f64>(), 0.0);
    let (stats, _) = e.metrics(&[42.0]).unwrap();
    assert_eq!(stats[0], 1.0);
    assert!((stats[1] - 42.0).abs() < 1e-3);
}

#[test]
fn auto_engine_reports_backend() {
    let eng = MetricsEngine::auto();
    // Either backend must produce sane numbers.
    let m = eng.metrics(&[1.0, 2.0, 3.0]).unwrap();
    assert_eq!(m.count, 3.0);
    assert!(m.backend == "pjrt" || m.backend == "fallback");
}
