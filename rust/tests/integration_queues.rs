//! Cross-module integration: every registry algorithm through the full
//! harness (multi-thread pairs + random workloads), with history recording
//! and linearizability verification end to end.

use std::sync::Arc;

use persiq::harness::runner::{drain_all, run_workload, RunConfig};
use persiq::harness::Workload;
use persiq::pmem::{PmemConfig, PmemPool};
use persiq::queues::{registry, QueueConfig, QueueCtx};
use persiq::verify::{check_relaxed, relaxation_for, History};

fn ctx(nthreads: usize) -> QueueCtx {
    QueueCtx {
        pool: Arc::new(PmemPool::new(PmemConfig::default().with_capacity(1 << 22).with_seed(7))),
        nthreads,
        cfg: QueueConfig::default(),
    }
}

#[test]
fn every_algorithm_passes_verified_pairs_workload() {
    for (name, ctor) in registry() {
        let c = ctx(4);
        let q = ctor(&c);
        let r = run_workload(
            &c.pool,
            &q,
            &RunConfig { nthreads: 4, total_ops: 20_000, record: true, ..Default::default() },
        );
        assert_eq!(r.ops_done, 20_000, "{name}");
        let drained = drain_all(&q, 0);
        let h = History::from_logs(r.logs, drained);
        let rep = check_relaxed(&h, relaxation_for(name, 4, &c.cfg));
        assert!(rep.ok(), "{name}: {:?}", rep.violations);
        assert_eq!(rep.enq_completed, 10_000, "{name}");
    }
}

#[test]
fn every_algorithm_passes_random_workload() {
    for (name, ctor) in registry() {
        let c = ctx(4);
        let q = ctor(&c);
        let r = run_workload(
            &c.pool,
            &q,
            &RunConfig {
                nthreads: 4,
                total_ops: 16_000,
                workload: Workload::Random5050,
                record: true,
                seed: 99,
                ..Default::default()
            },
        );
        assert_eq!(r.ops_done, 16_000, "{name}");
        let drained = drain_all(&q, 0);
        let h = History::from_logs(r.logs, drained);
        let rep = check_relaxed(&h, relaxation_for(name, 4, &c.cfg));
        assert!(rep.ok(), "{name}: {:?}", rep.violations);
    }
}

#[test]
#[ignore = "perf-shape assertion (Fig 2 ordering): the virtual-time signal depends on \
            real thread interleavings, so small/loaded CI hosts can distort combining \
            batch sizes; run explicitly with `cargo test -- --ignored` on a quiet host"]
fn virtual_time_orders_algorithms_as_the_paper_claims() {
    // Fig 2's headline at moderate simulated parallelism: PerLCRQ beats
    // PBQueue by >= 2x; PerLCRQ-PHead falls below plain PerLCRQ.
    let point = |algo: &str| {
        let c = ctx(16);
        let q = persiq::queues::by_name(algo).unwrap()(&c);
        run_workload(
            &c.pool,
            &q,
            &RunConfig { nthreads: 16, total_ops: 30_000, ..Default::default() },
        )
        .sim_mops
    };
    let perlcrq = point("perlcrq");
    let pbq = point("pbqueue");
    let phead = point("perlcrq-phead");
    assert!(
        perlcrq > 2.0 * pbq,
        "PerLCRQ ({perlcrq:.2}) must be >= 2x PBQueue ({pbq:.2})"
    );
    assert!(
        phead < perlcrq / 2.0,
        "PHead ({phead:.2}) must collapse vs PerLCRQ ({perlcrq:.2})"
    );
}

#[test]
fn persistence_instruction_counts_match_paper() {
    // PerLCRQ: exactly one pwb + one psync per op in steady state.
    let c = ctx(2);
    let q = persiq::queues::by_name("perlcrq").unwrap()(&c);
    let r = run_workload(
        &c.pool,
        &q,
        &RunConfig { nthreads: 2, total_ops: 10_000, ..Default::default() },
    );
    let t = c.pool.stats.total();
    let pwbs_per_op = t.pwbs as f64 / r.ops_done as f64;
    assert!(
        (pwbs_per_op - 1.0).abs() < 0.05,
        "PerLCRQ must do ~1 pwb/op, got {pwbs_per_op:.3}"
    );
}
