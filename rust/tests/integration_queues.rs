//! Cross-module integration: every registry algorithm through the full
//! harness (multi-thread pairs + random workloads), with history recording
//! and linearizability verification end to end.

use std::sync::Arc;

use persiq::harness::runner::{drain_all, run_workload, RunConfig};
use persiq::harness::Workload;
use persiq::pmem::{PmemConfig, PmemPool, Topology};
use persiq::queues::{
    persistent_by_name, registry, ConcurrentQueue, PersistentQueue, QueueConfig, QueueCtx,
};
use persiq::verify::{check_with, options_for, History};

fn ctx(nthreads: usize) -> QueueCtx {
    QueueCtx::single(
        PmemConfig::default().with_capacity(1 << 22).with_seed(7),
        nthreads,
        QueueConfig::default(),
    )
}

/// Build `name` through its persistent constructor when it has one, so the
/// test can `quiesce()` thread-buffered state (sharded batch logs,
/// blockfifo open blocks) before the final drain — without it, items a
/// worker left buffered at thread exit would read as losses.
fn build(name: &str, c: &QueueCtx) -> (Arc<dyn ConcurrentQueue>, Option<Arc<dyn PersistentQueue>>) {
    match persistent_by_name(name) {
        Some(p) => {
            let pq = p(c);
            (Arc::clone(&pq) as _, Some(pq))
        }
        None => {
            let ctor = registry()
                .into_iter()
                .find(|(n, _)| *n == name)
                .map(|(_, f)| f)
                .expect("registry name");
            (ctor(c), None)
        }
    }
}

#[test]
fn every_algorithm_passes_verified_pairs_workload() {
    for (name, _) in registry() {
        let c = ctx(4);
        let (q, pq) = build(name, &c);
        let r = run_workload(
            &c.topo,
            &q,
            &RunConfig { nthreads: 4, total_ops: 20_000, record: true, ..Default::default() },
        );
        assert_eq!(r.ops_done, 20_000, "{name}");
        if let Some(p) = &pq {
            p.quiesce();
        }
        let drained = drain_all(&q, 0);
        let h = History::from_logs(r.logs, drained);
        // No crash in this test (0 crashed epochs): the trailing windows
        // stay closed and only the algorithm's relaxation/EMPTY policy
        // applies.
        let rep = check_with(&h, &options_for(name, 4, &c.cfg, 0));
        assert!(rep.ok(), "{name}: {:?}", rep.violations);
        assert_eq!(rep.enq_completed, 10_000, "{name}");
    }
}

#[test]
fn every_algorithm_passes_random_workload() {
    for (name, _) in registry() {
        let c = ctx(4);
        let (q, pq) = build(name, &c);
        let r = run_workload(
            &c.topo,
            &q,
            &RunConfig {
                nthreads: 4,
                total_ops: 16_000,
                workload: Workload::Random5050,
                record: true,
                seed: 99,
                ..Default::default()
            },
        );
        assert_eq!(r.ops_done, 16_000, "{name}");
        if let Some(p) = &pq {
            p.quiesce();
        }
        let drained = drain_all(&q, 0);
        let h = History::from_logs(r.logs, drained);
        let rep = check_with(&h, &options_for(name, 4, &c.cfg, 0));
        assert!(rep.ok(), "{name}: {:?}", rep.violations);
    }
}

#[test]
#[ignore = "perf-shape assertion (Fig 2 ordering): the virtual-time signal depends on \
            real thread interleavings, so small/loaded CI hosts can distort combining \
            batch sizes; run explicitly with `cargo test -- --ignored` on a quiet host"]
fn virtual_time_orders_algorithms_as_the_paper_claims() {
    // Fig 2's headline at moderate simulated parallelism: PerLCRQ beats
    // PBQueue by >= 2x; PerLCRQ-PHead falls below plain PerLCRQ.
    let point = |algo: &str| {
        let c = ctx(16);
        let q = persiq::queues::by_name(algo).unwrap()(&c);
        run_workload(
            &c.topo,
            &q,
            &RunConfig { nthreads: 16, total_ops: 30_000, ..Default::default() },
        )
        .sim_mops
    };
    let perlcrq = point("perlcrq");
    let pbq = point("pbqueue");
    let phead = point("perlcrq-phead");
    assert!(
        perlcrq > 2.0 * pbq,
        "PerLCRQ ({perlcrq:.2}) must be >= 2x PBQueue ({pbq:.2})"
    );
    assert!(
        phead < perlcrq / 2.0,
        "PHead ({phead:.2}) must collapse vs PerLCRQ ({perlcrq:.2})"
    );
}

#[test]
fn persistence_instruction_counts_match_paper() {
    // PerLCRQ: exactly one pwb + one psync per op in steady state.
    let c = ctx(2);
    let q = persiq::queues::by_name("perlcrq").unwrap()(&c);
    let r = run_workload(
        &c.topo,
        &q,
        &RunConfig { nthreads: 2, total_ops: 10_000, ..Default::default() },
    );
    let t = c.topo.stats_total();
    let pwbs_per_op = t.pwbs as f64 / r.ops_done as f64;
    assert!(
        (pwbs_per_op - 1.0).abs() < 0.05,
        "PerLCRQ must do ~1 pwb/op, got {pwbs_per_op:.3}"
    );
}

#[test]
fn single_pool_topology_matches_bare_pool_costs_and_history() {
    // The refactor's compatibility bar: an algorithm built on
    // Topology::single's primary pool must produce the same delivery
    // order AND the same virtual time as one built on a bare PmemPool
    // with the identical config.
    let pcfg = || PmemConfig::default().with_capacity(1 << 22).with_seed(7);
    let run = |pool: &Arc<PmemPool>| -> (Vec<u64>, u64) {
        let q = persiq::queues::perlcrq::PerLcrq::new(pool, 2, QueueConfig::default());
        pool.set_active_threads(2);
        for v in 0..256u64 {
            q.enqueue(0, v).unwrap();
        }
        let mut out = Vec::new();
        while let Some(v) = q.dequeue(1).unwrap() {
            out.push(v);
        }
        (out, pool.max_vtime())
    };
    let bare = Arc::new(PmemPool::new(pcfg()));
    let topo = Topology::single(pcfg());
    let (h_bare, t_bare) = run(&bare);
    let (h_topo, t_topo) = run(topo.primary());
    assert_eq!(h_bare, h_topo, "degenerate topology must not change the history");
    assert_eq!(t_bare, t_topo, "degenerate topology must charge identical costs");
}

#[test]
fn sharded_runs_identically_on_every_placement_at_one_pool() {
    // All three placement policies collapse to the same dispatch on a
    // single pool: a deterministic single-threaded run through the full
    // harness yields the exact same delivery order.
    use persiq::pmem::PlacementPolicy;
    let histories: Vec<Vec<u64>> = ["interleave", "colocate", "pinned:0"]
        .iter()
        .map(|p| {
            let mut cfg = QueueConfig { shards: 4, batch: 4, ..Default::default() };
            cfg.placement = PlacementPolicy::parse(p).unwrap();
            let c = QueueCtx::single(
                PmemConfig::default().with_capacity(1 << 22).with_seed(7),
                1,
                cfg,
            );
            let q = persiq::queues::by_name("sharded-perlcrq").unwrap()(&c);
            let r = run_workload(
                &c.topo,
                &q,
                &RunConfig {
                    nthreads: 1,
                    total_ops: 8_000,
                    workload: Workload::EnqOnly,
                    ..Default::default()
                },
            );
            assert_eq!(r.ops_done, 8_000, "{p}");
            drain_all(&q, 0)
        })
        .collect();
    assert_eq!(histories[0].len(), 8_000);
    assert_eq!(histories[0], histories[1], "colocate must degenerate to interleave");
    assert_eq!(histories[0], histories[2], "pinned:0 must degenerate to interleave");
}
