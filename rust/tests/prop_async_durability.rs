//! Crash-during-async-flush property tests: the durability-gated
//! completion invariant of `queues::asyncq` under randomized crash
//! cycles, across several shard/batch/pool configurations.
//!
//! The contract under test (see `queues/asyncq` docs):
//!
//! > a future never resolves successfully before the `psync` covering its
//! > operation retired.
//!
//! Observable consequences, asserted here:
//!
//! 1. **Resolved enqueues survive** — every value whose `EnqFuture`
//!    resolved `Ok` is found again (as a resolved dequeue or in the final
//!    drain), except for at most `failed_deq` values that an in-flight
//!    (error-resolved) dequeue may have durably consumed without
//!    returning.
//! 2. **Resolved dequeues never redeliver** — no value appears twice
//!    across resolved dequeues + the final drain.
//! 3. **Checker-clean with ZERO allowances** — a history recorded at the
//!    async boundaries passes the durable-linearizability checker with
//!    `trailing_loss_per_thread = trailing_redelivery_per_thread = 0`:
//!    the allowances the *sync* batched API needs (PRs 1–2) exist
//!    precisely because returns race durability, and the async API closes
//!    that race.

use std::sync::Arc;

use persiq::harness::{run_async_workload, AsyncRunConfig, Workload};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::{CostModel, PlacementPolicy, PmemConfig, Topology};
use persiq::queues::asyncq::AsyncCfg;
use persiq::queues::sharded::ShardedQueue;
use persiq::queues::{ConcurrentQueue, PersistentQueue, QueueConfig};
use persiq::util::rng::Xoshiro256;
use persiq::verify::{check_with, relaxation_for, CheckOptions, History};

const PRODUCERS: usize = 4;

struct Scenario {
    pools: usize,
    shards: usize,
    batch: usize,
    batch_deq: usize,
    placement: PlacementPolicy,
    flushers: usize,
    depth: usize,
}

fn scenarios() -> Vec<Scenario> {
    vec![
        Scenario {
            pools: 1,
            shards: 4,
            batch: 4,
            batch_deq: 4,
            placement: PlacementPolicy::Interleave,
            flushers: 1,
            depth: 8,
        },
        Scenario {
            pools: 2,
            shards: 2,
            batch: 8,
            batch_deq: 2,
            placement: PlacementPolicy::Colocate,
            flushers: 2,
            depth: 16,
        },
        Scenario {
            pools: 2,
            shards: 8,
            batch: 2,
            batch_deq: 8,
            placement: PlacementPolicy::Interleave,
            flushers: 2,
            depth: 4,
        },
    ]
}

fn mk(s: &Scenario, evict: f64, pending: f64, seed: u64) -> (Topology, Arc<ShardedQueue>) {
    let topo = Topology::new(
        PmemConfig {
            capacity_words: 1 << 23,
            cost: CostModel::zero(),
            evict_prob: evict,
            pending_flush_prob: pending,
            seed,
        },
        s.pools,
    );
    let cfg = QueueConfig {
        shards: s.shards,
        batch: s.batch,
        batch_deq: s.batch_deq,
        ring_size: 256,
        placement: s.placement.clone(),
        ..Default::default()
    };
    let q = Arc::new(
        ShardedQueue::new_perlcrq(&topo, PRODUCERS + s.flushers, cfg).unwrap(),
    );
    (topo, q)
}

fn drain(q: &Arc<ShardedQueue>) -> Vec<u64> {
    let mut out = Vec::new();
    while let Some(v) = q.dequeue(0).unwrap() {
        out.push(v);
    }
    out
}

/// Invariants 1 + 2, deterministic-loss configuration (`evict = pending
/// = 0`): nothing unflushed ever survives a crash, so "durable" and
/// "flushed" coincide and the set arithmetic is exact.
#[test]
fn resolved_futures_are_durable_across_crash_cycles() {
    install_quiet_crash_hook();
    for (si, s) in scenarios().iter().enumerate() {
        let (topo, q) = mk(s, 0.0, 0.0, 100 + si as u64);
        let mut rng = Xoshiro256::seed_from(7 + si as u64);
        let mut enq_resolved: Vec<u64> = Vec::new();
        let mut deq_resolved: Vec<u64> = Vec::new();
        let mut inflight_deqs = 0u64;
        for cycle in 0..4u64 {
            topo.arm_crash_after(2_000 + rng.next_below(4_000));
            let rc = AsyncRunConfig {
                producers: PRODUCERS,
                total_ops: 60_000,
                workload: Workload::Pairs,
                seed: 1_000 * (si as u64 + 1) + cycle,
                salt: cycle + 1,
                record: false,
                window: s.depth,
                acfg: AsyncCfg { flush_us: 200, depth: s.depth, flushers: s.flushers },
            };
            let r = run_async_workload(&topo, &q, &rc);
            assert!(r.crashed, "crash must trip mid-run (scenario {si}, cycle {cycle})");
            enq_resolved.extend(r.enq_resolved);
            deq_resolved.extend(r.deq_resolved);
            // The TIGHT loss budget: dequeues that executed against the
            // queue but whose flush never retired. (r.failed_deq would
            // also count ring-drained ops that never touched the queue —
            // a budget that scales with the window and could hide real
            // losses.)
            inflight_deqs += r.stats.crash_inflight_deqs;
            topo.crash(&mut rng);
            q.recover(topo.primary());
        }
        let drained = drain(&q);

        // 2: resolved dequeues never redeliver (and the single-threaded
        // drain itself cannot duplicate).
        let mut delivered: Vec<u64> = deq_resolved.iter().copied().chain(drained.clone()).collect();
        let n = delivered.len();
        delivered.sort_unstable();
        delivered.dedup();
        assert_eq!(
            delivered.len(),
            n,
            "scenario {si}: a durably-consumed (resolved) value was redelivered"
        );

        // 1: resolved enqueues survive, modulo the in-flight-dequeue
        // budget (an error-resolved dequeue may have durably consumed a
        // value without returning it — §4 Scenario 2, async edition).
        let delivered_set: std::collections::HashSet<u64> = delivered.into_iter().collect();
        let missing: Vec<u64> = enq_resolved
            .iter()
            .copied()
            .filter(|v| !delivered_set.contains(v))
            .collect();
        assert!(
            missing.len() as u64 <= inflight_deqs,
            "scenario {si}: {} resolved enqueues vanished but only {} executed \
             in-flight dequeues could have consumed them (missing sample: {:?})",
            missing.len(),
            inflight_deqs,
            &missing[..missing.len().min(5)]
        );
    }
}

/// Invariant 3: recorded async histories pass the checker with zero
/// trailing allowances under randomized crash nondeterminism (evict and
/// pending-flush probabilities on), riding the V4/trailing-redelivery
/// gating machinery of PRs 1–2 — which the async path must never need.
#[test]
fn async_histories_check_clean_with_zero_allowances() {
    install_quiet_crash_hook();
    for (si, s) in scenarios().iter().enumerate() {
        let (topo, q) = mk(s, 0.3, 0.5, 200 + si as u64);
        let mut rng = Xoshiro256::seed_from(17 + si as u64);
        let mut logs = Vec::new();
        let mut inflight_budget = 0u64;
        let cycles = 3u64;
        for cycle in 0..cycles {
            topo.arm_crash_after(2_500 + rng.next_below(4_000));
            let rc = AsyncRunConfig {
                producers: PRODUCERS,
                total_ops: 50_000,
                workload: Workload::Pairs,
                seed: 2_000 * (si as u64 + 1) + cycle,
                salt: cycle + 1,
                record: true,
                window: s.depth,
                acfg: AsyncCfg { flush_us: 200, depth: s.depth, flushers: s.flushers },
            };
            let r = run_async_workload(&topo, &q, &rc);
            logs.extend(r.logs);
            inflight_budget += r.stats.crash_inflight_deqs;
            topo.crash(&mut rng);
            q.recover(topo.primary());
        }
        let history = History::from_logs(logs, drain(&q));
        let qcfg = QueueConfig {
            shards: s.shards,
            batch: s.batch,
            batch_deq: s.batch_deq,
            ..Default::default()
        };
        let rep = check_with(
            &history,
            &CheckOptions {
                max_report: 5,
                relaxation: relaxation_for(
                    "sharded-perlcrq",
                    PRODUCERS + s.flushers,
                    &qcfg,
                ),
                // THE point: no trailing-loss, no trailing-redelivery.
                // Resolution is gated on durability, so the buffered-
                // durability excuses must never be needed.
                trailing_loss_per_thread: 0,
                trailing_redelivery_per_thread: 0,
                crashed_epochs: cycles,
                check_empty: false,
                collect_overtakes: false,
            },
        );
        assert!(
            rep.ok(),
            "scenario {si}: async history failed with zero allowances: {:?} \
             (enq={} deq={} drained={} pending={})",
            rep.violations,
            rep.enq_completed,
            rep.deq_values,
            rep.drained,
            rep.pending_deqs,
        );
        assert!(rep.enq_completed > 0, "scenario {si}: degenerate history");
        assert_eq!(rep.absorbed_trailing, 0);
        assert_eq!(rep.absorbed_redelivered, 0);
        // The executed-vs-submitted tightening: recorded async histories
        // carry `DeqExecuted` markers, so the checker's V2 loss budget is
        // exactly the combiner's crash-in-flight dequeues — it must not
        // scale with the (much larger) open future window.
        assert!(
            rep.pending_deqs as u64 <= inflight_budget,
            "scenario {si}: checker pending budget {} exceeds the combiner's \
             crash-in-flight count {} — the DeqExecuted markers are not tightening it",
            rep.pending_deqs,
            inflight_budget
        );
    }
}
