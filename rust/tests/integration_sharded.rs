//! End-to-end coverage of the sharded + batched queue layer: relaxed-FIFO
//! durable-linearizability across crash cycles, contention scaling of the
//! shard sweep, psync amortization under batching, and the broker riding
//! on the sharded work queue.

use std::sync::Arc;

use persiq::coordinator::{run_service, Broker, ServiceConfig};
use persiq::harness::runner::{drain_all, run_workload, RunConfig};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::{CostModel, PlacementPolicy, PmemConfig, Topology};
use persiq::queues::{persistent_by_name, ConcurrentQueue, QueueConfig, QueueCtx};
use persiq::util::rng::Xoshiro256;
use persiq::verify::{check_with, shard_relaxation, CheckOptions, History};

fn sharded_ctx(
    nthreads: usize,
    shards: usize,
    batch: usize,
    batch_deq: usize,
    cap: usize,
) -> QueueCtx {
    sharded_ctx_topo(nthreads, shards, batch, batch_deq, cap, 1, PlacementPolicy::Interleave)
}

#[allow(clippy::too_many_arguments)]
fn sharded_ctx_topo(
    nthreads: usize,
    shards: usize,
    batch: usize,
    batch_deq: usize,
    cap: usize,
    pools: usize,
    placement: PlacementPolicy,
) -> QueueCtx {
    QueueCtx {
        topo: Topology::new(
            PmemConfig {
                capacity_words: cap,
                cost: CostModel::default(),
                evict_prob: 0.25,
                pending_flush_prob: 0.5,
                seed: 23,
            },
            pools,
        ),
        nthreads,
        cfg: QueueConfig {
            shards,
            batch,
            batch_deq,
            ring_size: 256,
            placement,
            ..Default::default()
        },
    }
}

/// Drive `sharded-perlcrq` through recorded crash cycles and check the
/// history with the given options. Mirrors `persiq verify`.
fn verify_sharded(shards: usize, batch: usize, batch_deq: usize, cycles: usize, seed: u64) {
    verify_sharded_topo(shards, batch, batch_deq, cycles, seed, 1, PlacementPolicy::Interleave);
}

fn verify_sharded_topo(
    shards: usize,
    batch: usize,
    batch_deq: usize,
    cycles: usize,
    seed: u64,
    pools: usize,
    placement: PlacementPolicy,
) {
    install_quiet_crash_hook();
    let nthreads = 4;
    let ctx =
        sharded_ctx_topo(nthreads, shards, batch, batch_deq, 1 << 23, pools, placement.clone());
    let q = persistent_by_name("sharded-perlcrq").unwrap()(&ctx);
    let as_conc: Arc<dyn ConcurrentQueue> = Arc::clone(&q) as _;
    let mut rng = Xoshiro256::seed_from(seed);
    let mut logs = Vec::new();
    for cycle in 0..cycles {
        ctx.topo.arm_crash_after(20_000);
        let rc = RunConfig {
            nthreads,
            total_ops: 30_000,
            record: true,
            salt: cycle as u64 + 1,
            seed: seed ^ (cycle as u64) << 16,
            ..Default::default()
        };
        let r = run_workload(&ctx.topo, &as_conc, &rc);
        logs.extend(r.logs);
        ctx.topo.crash(&mut rng);
        q.recover(ctx.pool());
    }
    let drained = drain_all(&as_conc, 0);
    let history = History::from_logs(logs, drained);
    let opts = CheckOptions {
        max_report: 10,
        relaxation: shard_relaxation(nthreads, shards, batch.max(batch_deq)),
        trailing_loss_per_thread: batch.saturating_sub(1),
        trailing_redelivery_per_thread: batch_deq.saturating_sub(1),
        crashed_epochs: cycles as u64,
        check_empty: batch <= 1,
        ..Default::default()
    };
    let rep = check_with(&history, &opts);
    assert!(
        rep.ok(),
        "shards={shards} batch={batch} batch_deq={batch_deq} pools={pools} \
         placement={placement}: violations {:?} (max_overtakes={})",
        rep.violations,
        rep.max_overtakes
    );
    assert!(rep.enq_completed > 0 && rep.deq_values > 0);
}

#[test]
fn sharded_relaxed_durable_linearizability_10_cycles() {
    verify_sharded(4, 1, 1, 10, 0xA11CE);
}

#[test]
fn sharded_single_shard_10_cycles() {
    verify_sharded(1, 1, 1, 10, 0xB0B);
}

#[test]
fn batched_relaxed_durable_linearizability_10_cycles() {
    verify_sharded(4, 4, 1, 10, 0xCAFE);
}

#[test]
fn batched_max_batch_cycles() {
    verify_sharded(2, 8, 1, 6, 0xD00D);
}

#[test]
fn batched_dequeues_durable_linearizability_10_cycles() {
    verify_sharded(4, 1, 4, 10, 0xDE0);
}

#[test]
fn both_sides_batched_cycles() {
    verify_sharded(4, 4, 4, 10, 0xB07);
}

#[test]
fn both_sides_max_batch_cycles() {
    verify_sharded(2, 8, 8, 6, 0xFEED);
}

#[test]
fn two_pool_interleave_batched_cycles() {
    // Batches span both pools: every flush issues one psync per touched
    // pool and crashes land between them (the torn cross-pool flush
    // window) — the relaxed checker must still accept the history.
    verify_sharded_topo(4, 4, 4, 10, 0x2B001, 2, PlacementPolicy::Interleave);
}

#[test]
fn two_pool_colocate_batched_cycles() {
    verify_sharded_topo(4, 4, 4, 10, 0x2B002, 2, PlacementPolicy::Colocate);
}

#[test]
fn two_pool_pinned_batched_cycles() {
    // Everything pinned onto pool 1 while logs stay on each thread's home
    // pool: enqueue cells and batch logs durably commit on different
    // pools for the socket-0 threads.
    verify_sharded_topo(4, 4, 4, 8, 0x2B003, 2, PlacementPolicy::Pinned(vec![1]));
}

#[test]
fn four_pool_colocate_unbatched_cycles() {
    verify_sharded_topo(8, 1, 1, 6, 0x2B004, 4, PlacementPolicy::Colocate);
}

fn sim_mops(shards: usize, batch: usize, nthreads: usize, ops: u64) -> f64 {
    let ctx = sharded_ctx(nthreads, shards, batch, 1, 1 << 23);
    let q = persistent_by_name("sharded-perlcrq").unwrap()(&ctx);
    let as_conc: Arc<dyn ConcurrentQueue> = Arc::clone(&q) as _;
    let rc = RunConfig { nthreads, total_ops: ops, seed: 7, ..Default::default() };
    run_workload(&ctx.topo, &as_conc, &rc).sim_mops
}

#[test]
fn eight_shards_outscale_one_shard_at_eight_threads() {
    let s1 = sim_mops(1, 1, 8, 40_000);
    let s8 = sim_mops(8, 1, 8, 40_000);
    assert!(
        s8 > s1 * 1.2,
        "8 shards ({s8:.2} Mops) must beat 1 shard ({s1:.2} Mops) at 8 threads"
    );
}

#[test]
fn batching_amortizes_psyncs_per_op() {
    let ctx = sharded_ctx(4, 4, 8, 1, 1 << 22);
    let q = persistent_by_name("sharded-perlcrq").unwrap()(&ctx);
    let as_conc: Arc<dyn ConcurrentQueue> = Arc::clone(&q) as _;
    let rc = RunConfig { nthreads: 4, total_ops: 20_000, seed: 11, ..Default::default() };
    let r = run_workload(&ctx.topo, &as_conc, &rc);
    let stats = ctx.topo.stats_total();
    let psyncs_per_op = stats.psyncs as f64 / r.ops_done.max(1) as f64;
    // Half the ops are dequeues (one psync each); enqueues contribute
    // ~1/8 psync each. Expect well under the per-op regime's ~1.0.
    assert!(
        psyncs_per_op < 0.75,
        "batch=8 should amortize enqueue psyncs (got {psyncs_per_op:.2}/op)"
    );
}

#[test]
fn both_sides_batching_amortizes_psyncs_per_op() {
    // batch = batch_deq = 8: both endpoints group-commit, so the pairs
    // workload should land well under the per-op regime's ~1 psync/op —
    // target < 2/K on the combined stream (enqueues AND dequeues each
    // contribute ~1/K).
    let k = 8usize;
    let ctx = sharded_ctx(4, 4, k, k, 1 << 22);
    let q = persistent_by_name("sharded-perlcrq").unwrap()(&ctx);
    let as_conc: Arc<dyn ConcurrentQueue> = Arc::clone(&q) as _;
    let rc = RunConfig { nthreads: 4, total_ops: 20_000, seed: 11, ..Default::default() };
    let r = run_workload(&ctx.topo, &as_conc, &rc);
    let stats = ctx.topo.stats_total();
    let psyncs_per_op = stats.psyncs as f64 / r.ops_done.max(1) as f64;
    assert!(
        psyncs_per_op < 2.0 / k as f64,
        "batch=batch_deq={k} should amortize both endpoints \
         (got {psyncs_per_op:.3}/op, want < {:.3})",
        2.0 / k as f64
    );
}

#[test]
fn broker_on_batched_dequeue_work_queue_exactly_once_across_crashes() {
    // The broker's ack path rides the dequeue log: handles consumed from
    // the work queue are logged and group-committed, and recover()'s
    // queue↔SubmitLog reconciliation stays exact — every job completes
    // exactly once even when the consuming dequeues crash mid-batch.
    install_quiet_crash_hook();
    let topo = Topology::single(PmemConfig {
        capacity_words: 1 << 23,
        evict_prob: 0.25,
        pending_flush_prob: 0.5,
        seed: 41,
        ..Default::default()
    });
    let qcfg =
        QueueConfig { shards: 4, batch: 4, batch_deq: 4, ring_size: 256, ..Default::default() };
    let broker = Arc::new(Broker::new_sharded(&topo, 4, 1 << 16, qcfg).unwrap());
    let rep = run_service(
        &topo,
        &broker,
        &ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 300,
            crash_cycles: 3,
            crash_steps: 30_000,
            seed: 6,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.crashes, 3);
    assert_eq!(
        rep.done, rep.submitted,
        "every submitted job must complete exactly once on the batched-dequeue broker: {rep:?}"
    );
    assert_eq!(rep.pending_after, 0);
}

#[test]
fn broker_on_sharded_queue_exactly_once_across_crashes() {
    install_quiet_crash_hook();
    let topo = Topology::single(PmemConfig {
        capacity_words: 1 << 23,
        evict_prob: 0.25,
        pending_flush_prob: 0.5,
        seed: 31,
        ..Default::default()
    });
    let qcfg = QueueConfig { shards: 4, batch: 4, ring_size: 256, ..Default::default() };
    let broker = Arc::new(Broker::new_sharded(&topo, 4, 1 << 16, qcfg).unwrap());
    let rep = run_service(
        &topo,
        &broker,
        &ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 300,
            crash_cycles: 3,
            crash_steps: 30_000,
            seed: 5,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.crashes, 3);
    assert_eq!(
        rep.done, rep.submitted,
        "every submitted job must complete exactly once on the sharded broker: {rep:?}"
    );
    assert_eq!(rep.pending_after, 0);
}

#[test]
fn broker_on_sharded_queue_clean_run() {
    let topo = Topology::single(PmemConfig {
        capacity_words: 1 << 22,
        cost: CostModel::zero(),
        evict_prob: 0.0,
        pending_flush_prob: 0.0,
        seed: 37,
    });
    let qcfg = QueueConfig { shards: 2, batch: 4, ring_size: 256, ..Default::default() };
    let broker = Arc::new(Broker::new_sharded(&topo, 4, 1 << 16, qcfg).unwrap());
    let rep = run_service(
        &topo,
        &broker,
        &ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 200,
            crash_cycles: 0,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.submitted, 400);
    assert_eq!(rep.done, 400, "{rep:?}");
    assert_eq!(rep.pending_after, 0);
}

#[test]
fn broker_on_two_pool_colocated_queue_exactly_once_across_crashes() {
    // The full stack on a 2-socket topology: sharded work queue with
    // colocated placement, job records + submit logs on per-thread home
    // pools, coordinated crashes, reconciliation walking both pools.
    install_quiet_crash_hook();
    let topo = Topology::new(
        PmemConfig {
            capacity_words: 1 << 23,
            evict_prob: 0.25,
            pending_flush_prob: 0.5,
            seed: 43,
            ..Default::default()
        },
        2,
    );
    let qcfg = QueueConfig {
        shards: 4,
        batch: 4,
        batch_deq: 4,
        ring_size: 256,
        placement: PlacementPolicy::Colocate,
        ..Default::default()
    };
    let broker = Arc::new(Broker::new_sharded(&topo, 4, 1 << 16, qcfg).unwrap());
    let rep = run_service(
        &topo,
        &broker,
        &ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 300,
            crash_cycles: 3,
            crash_steps: 30_000,
            seed: 7,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.crashes, 3);
    assert_eq!(
        rep.done, rep.submitted,
        "every submitted job must complete exactly once on the 2-pool broker: {rep:?}"
    );
    assert_eq!(rep.pending_after, 0);
    assert_eq!(broker.reconcile_report(0).mismatches(), 0);
}
