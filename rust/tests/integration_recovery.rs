//! Crash/recovery integration: the §5 failure framework over every
//! persistent algorithm, including repeated cycles and double-crashes.

use std::sync::Arc;

use persiq::harness::failure::{run_cycles, CycleConfig};
use persiq::harness::runner::{drain_all, run_workload, RunConfig};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::PmemConfig;
use persiq::queues::{persistent_registry, QueueConfig, QueueCtx};
use persiq::util::rng::Xoshiro256;
use persiq::verify::{check_with, options_for, History};

fn ctx() -> QueueCtx {
    QueueCtx::single(
        PmemConfig {
            capacity_words: 1 << 23,
            evict_prob: 0.25,
            pending_flush_prob: 0.5,
            seed: 31,
            ..Default::default()
        },
        4,
        QueueConfig::default(),
    )
}

#[test]
fn all_persistent_queues_survive_cycles() {
    install_quiet_crash_hook();
    for (name, ctor) in persistent_registry() {
        let c = ctx();
        let q = ctor(&c);
        let res = run_cycles(
            &c.topo,
            &q,
            &CycleConfig {
                cycles: 3,
                steps: 25_000,
                run: RunConfig { nthreads: 4, total_ops: u64::MAX / 2, ..Default::default() },
                seed: 5,
            },
        );
        assert_eq!(res.len(), 3, "{name}");
        for r in &res {
            assert!(r.run.crashed, "{name}: run must be interrupted");
        }
        // Queue alive after final recovery. The quiesce publishes any
        // thread-buffered state (a no-op for per-op queues; blockfifo
        // seals tid 0's open block, without which the item would be
        // invisible to tid 1).
        q.enqueue(0, 4242).unwrap();
        q.quiesce();
        assert!(q.dequeue(1).unwrap().is_some(), "{name}");
    }
}

#[test]
fn verified_crash_cycles_for_all_persistent_queues() {
    install_quiet_crash_hook();
    for (name, ctor) in persistent_registry() {
        let c = ctx();
        let q = ctor(&c);
        let qc: Arc<dyn persiq::queues::ConcurrentQueue> = Arc::clone(&q) as _;
        let mut rng = Xoshiro256::seed_from(17);
        let mut logs = Vec::new();
        for cycle in 0..3 {
            c.topo.arm_crash_after(20_000);
            let r = run_workload(
                &c.topo,
                &qc,
                &RunConfig {
                    nthreads: 4,
                    total_ops: 40_000,
                    record: true,
                    salt: cycle + 1,
                    seed: 100 + cycle,
                    ..Default::default()
                },
            );
            logs.extend(r.logs);
            c.topo.crash(&mut rng);
            q.recover(c.pool());
        }
        let drained = drain_all(&qc, 0);
        let h = History::from_logs(logs, drained);
        // Each of the 3 cycles ended in a crash: the algorithm's policy
        // (relaxation + crash-gated trailing windows + EMPTY soundness)
        // comes from the same options_for the CLI uses.
        let rep = check_with(&h, &options_for(name, 4, &c.cfg, 3));
        assert!(rep.ok(), "{name}: {:?}", rep.violations);
    }
}

#[test]
fn double_crash_without_ops_is_stable() {
    install_quiet_crash_hook();
    for (name, ctor) in persistent_registry() {
        let c = ctx();
        let q = ctor(&c);
        for v in 0..50u64 {
            q.enqueue(0, v).unwrap();
        }
        // Publish thread-buffered state durably before crashing: without
        // it blockfifo's open tail block (49 mod 16 items) is legitimate
        // crash loss, and this test asserts exact survival.
        q.quiesce();
        let mut rng = Xoshiro256::seed_from(23);
        c.topo.crash(&mut rng);
        q.recover(c.pool());
        c.topo.crash(&mut rng);
        q.recover(c.pool());
        let mut out = Vec::new();
        while let Some(v) = q.dequeue(1).unwrap() {
            out.push(v);
        }
        if name.starts_with("blockfifo") {
            // Relaxed tier: lanes interleave, so only the set is exact.
            out.sort_unstable();
        }
        assert_eq!(out, (0..50).collect::<Vec<u64>>(), "{name}: loss after double crash");
    }
}

#[test]
fn recovery_cost_scales_with_scan_for_pure_periq() {
    install_quiet_crash_hook();
    // Small vs large op count before crash: pure PerIQ recovery loads grow.
    let measure = |steps: u64| {
        // evict_prob = 0: random eviction can persist the endpoints and
        // legitimately shortcut pure-PerIQ recovery, which is exactly the
        // variance this growth assertion must not depend on.
        let c = QueueCtx::single(
            PmemConfig {
                capacity_words: 1 << 23,
                evict_prob: 0.0,
                pending_flush_prob: 0.0,
                seed: 3,
                ..Default::default()
            },
            4,
            QueueConfig { iq_capacity: 1 << 19, ..Default::default() },
        );
        let q = persiq::queues::persistent_by_name("periq").unwrap()(&c);
        let res = run_cycles(
            &c.topo,
            &q,
            &CycleConfig {
                cycles: 2,
                steps,
                run: RunConfig { nthreads: 4, total_ops: u64::MAX / 2, ..Default::default() },
                seed: 9,
            },
        );
        res.iter().map(|r| r.recovery_loads).sum::<u64>() / res.len() as u64
    };
    // Wide separation + loose factor: crash-step jitter and scheduling
    // variance move individual points, but a 80x step gap must show.
    let small = measure(10_000);
    let big = measure(800_000);
    assert!(
        big > small * 2,
        "recovery scan must grow with ops before crash: {small} -> {big}"
    );
}
