//! Property tests for elastic re-sharding crash recovery: crashes are
//! driven into every phase of the `Active → Freezing → Active` state
//! machine (staging psync / freeze commit / partial residue drain /
//! retirement) by sweeping the armed step countdown across the whole
//! transition, plus randomized multi-cycle runs. After every crash,
//! recovery must land on **exactly one plan** with zero lost or
//! duplicated items beyond the documented allowances (trailing windows
//! for batched modes; none at all for per-op modes).

use std::sync::Arc;

use persiq::pmem::crash::{install_quiet_crash_hook, run_guarded};
use persiq::pmem::{CostModel, PmemConfig, Topology};
use persiq::queues::sharded::ShardedQueue;
use persiq::queues::{ConcurrentQueue, PersistentQueue, QueueConfig};
use persiq::util::rng::Xoshiro256;

fn mk(
    pools: usize,
    shards: usize,
    batch: usize,
    batch_deq: usize,
    pending: f64,
    evict: f64,
    seed: u64,
) -> (Topology, Arc<ShardedQueue>) {
    mk_cap(pools, shards, batch, batch_deq, pending, evict, seed, 1 << 22)
}

#[allow(clippy::too_many_arguments)]
fn mk_cap(
    pools: usize,
    shards: usize,
    batch: usize,
    batch_deq: usize,
    pending: f64,
    evict: f64,
    seed: u64,
    capacity_words: usize,
) -> (Topology, Arc<ShardedQueue>) {
    let topo = Topology::new(
        PmemConfig {
            capacity_words,
            cost: CostModel::zero(),
            evict_prob: evict,
            pending_flush_prob: pending,
            seed,
        },
        pools,
    );
    let cfg = QueueConfig { shards, batch, batch_deq, ring_size: 64, ..Default::default() };
    let q = Arc::new(ShardedQueue::new_perlcrq(&topo, 4, cfg).unwrap());
    (topo, q)
}

fn drain(q: &ShardedQueue, tid: usize) -> Vec<u64> {
    let mut out = Vec::new();
    while let Ok(Some(v)) = q.dequeue(tid) {
        out.push(v);
    }
    out
}

/// Sweep the armed crash countdown across the whole resize transition:
/// every `j` lands the crash at a different internal point (new-stripe
/// construction, record psync, freeze commit psync, immediate-retire
/// psync, or none — resize completes and the crash hits afterwards).
/// Pre-resize items are durably flushed, so recovery must deliver
/// exactly them — no loss, no duplication, single plan — at every `j`.
#[test]
fn crash_swept_through_every_resize_phase() {
    install_quiet_crash_hook();
    for (pools, batch, batch_deq) in [(1, 1, 1), (1, 4, 4), (2, 4, 1), (2, 4, 4)] {
        // Stride 1 over a window comfortably past a full resize's pmem
        // op count (new_k stripe constructions + 3 log psyncs + hints).
        // Small arenas: this builds a fresh topology per step.
        for j in 1..=160u64 {
            let (topo, q) =
                mk_cap(pools, 4, batch, batch_deq, 0.5, 0.3, 1000 + j, 1 << 18);
            for v in 0..24u64 {
                q.enqueue(0, v).unwrap();
            }
            q.flush_all(); // everything durable before the transition
            topo.arm_crash_after(j);
            let out = run_guarded(|| {
                let _ = q.resize(0, 7);
            });
            let mut rng = Xoshiro256::seed_from(2000 + j);
            topo.crash(&mut rng);
            q.recover(topo.primary());
            assert!(
                q.draining_info(0).is_none(),
                "j={j} b={batch}/{batch_deq} p={pools}: recovery left two plans"
            );
            let epoch = q.plan_epoch();
            assert!(
                epoch == 1 || epoch == 2,
                "j={j}: impossible plan epoch {epoch} (crashed={})",
                out.crashed()
            );
            let mut got = drain(&q, 0);
            let n = got.len();
            got.sort_unstable();
            got.dedup();
            assert_eq!(got.len(), n, "j={j} b={batch}/{batch_deq} p={pools}: duplicates");
            assert_eq!(
                got,
                (0..24).collect::<Vec<u64>>(),
                "j={j} b={batch}/{batch_deq} p={pools}: durably flushed items lost \
                 (epoch {epoch})"
            );
            // The queue is fully functional on the surviving plan.
            q.enqueue(1, 999).unwrap();
            q.flush_all();
            assert_eq!(q.dequeue(2).unwrap(), Some(999));
        }
    }
}

/// Crash landing around the flip's grace window while a worker hammers
/// the epoch-pinned hot path. The armed countdown (decremented by pmem
/// primitives on *both* threads) fires at an arbitrary point in the
/// transition — including while the resize thread is spinning out its
/// grace period with the worker pinned. The worker's `CrashSignal`
/// unwinds through its RAII pin guard, so recovery starts quiescent;
/// the decisive check is the **follow-up resize**: a pin leaked across
/// the crash would park that resize's grace wait forever (this test
/// hangs instead of failing an assertion).
#[test]
fn crash_during_grace_window_releases_pins() {
    install_quiet_crash_hook();
    for j in [4u64, 9, 17, 33, 57, 96] {
        let (topo, q) = mk_cap(1, 4, 4, 4, 0.5, 0.3, 500 + j, 1 << 18);
        for v in 0..24u64 {
            q.enqueue(0, v).unwrap();
        }
        q.flush_all();
        topo.arm_crash_after(j);
        let wq = Arc::clone(&q);
        let worker = std::thread::spawn(move || {
            let _ = run_guarded(|| {
                for i in 0..100_000u64 {
                    wq.enqueue(1, 1_000 + i).unwrap();
                    let _ = wq.dequeue(1).unwrap();
                }
            });
        });
        let _ = run_guarded(|| {
            let _ = q.resize(0, 6);
        });
        worker.join().unwrap();
        let mut rng = Xoshiro256::seed_from(700 + j);
        topo.crash(&mut rng);
        q.recover(topo.primary());
        assert!(q.draining_info(0).is_none(), "j={j}: recovery left two plans");
        let got = drain(&q, 0);
        let n = got.len();
        let mut sorted = got;
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), n, "j={j}: duplicate delivery");
        // Completes only if every pin taken before the crash was
        // released by the unwind.
        let e = q.resize(2, 3).expect("post-recovery resize must commit");
        assert_eq!(q.plan_epoch(), e, "j={j}: epoch hint out of step");
        assert!(
            q.draining_info(0).is_none(),
            "j={j}: empty-queue resize must retire immediately"
        );
        q.enqueue(3, 7).unwrap();
        q.flush_all();
        assert_eq!(q.dequeue(0).unwrap(), Some(7));
    }
}

/// Crash mid-drain: freeze with residue, consume part of it (per-op
/// durable consumption), crash, recover. Strict mode (`batch_deq = 1`)
/// allows no redelivery at all: returned + recovered-drain must be
/// exactly the original multiset.
#[test]
fn crash_mid_drain_partial_residue_strict() {
    install_quiet_crash_hook();
    for take in [0usize, 3, 9, 15] {
        let (topo, q) = mk(2, 4, 1, 1, 0.5, 0.3, 77 + take as u64);
        for v in 0..16u64 {
            q.enqueue(0, v).unwrap(); // per-op durable (batch = 1)
        }
        assert_eq!(q.resize(0, 2), Ok(2));
        let mut returned = Vec::new();
        for _ in 0..take {
            returned.push(q.dequeue(1).unwrap().expect("residue present"));
        }
        let mut rng = Xoshiro256::seed_from(3 + take as u64);
        topo.crash(&mut rng);
        q.recover(topo.primary());
        assert!(q.draining_info(0).is_none());
        assert_eq!(q.plan_epoch(), 2);
        returned.extend(drain(&q, 0));
        let n = returned.len();
        returned.sort_unstable();
        returned.dedup();
        assert_eq!(returned.len(), n, "take={take}: strict mode must never redeliver");
        assert_eq!(returned, (0..16).collect::<Vec<u64>>(), "take={take}: items lost");
    }
}

/// Randomized end-to-end: concurrent producers/consumers, a resize per
/// cycle at a random point (grow and shrink), crashes landing anywhere —
/// including inside the resize call itself — batched both sides. Across
/// all cycles nothing may ever be delivered twice (the trailing
/// redelivery allowance is crash-gated and per-value-chained; the
/// harness's unique values make any duplicate a hard failure here
/// because each cycle re-verifies convergence before continuing).
#[test]
fn randomized_resize_crash_cycles_never_duplicate() {
    install_quiet_crash_hook();
    for seed in [5u64, 6, 7] {
        let (topo, q) = mk(2, 4, 4, 4, 0.5, 0.3, seed);
        let mut rng = Xoshiro256::seed_from(seed * 31);
        let mut returned: Vec<u64> = Vec::new();
        for cycle in 0..3u64 {
            topo.arm_crash_after(1_500 + rng.next_below(2_500));
            let resize_at = rng.next_below(20_000);
            let target_k = [7usize, 2, 5][cycle as usize];
            let mut hs = Vec::new();
            for tid in 0..4usize {
                let q = Arc::clone(&q);
                let base = (seed * 10 + cycle) * 4_000_000 + tid as u64 * 1_000_000;
                hs.push(std::thread::spawn(move || {
                    let mut mine = Vec::new();
                    let _ = run_guarded(|| {
                        for i in 0..25_000u64 {
                            if tid == 0 && i == resize_at {
                                let _ = q.resize(tid, target_k);
                            }
                            q.enqueue(tid, base + i).unwrap();
                            if let Some(v) = q.dequeue(tid).unwrap() {
                                mine.push(v);
                            }
                        }
                    });
                    mine
                }));
            }
            for h in hs {
                returned.extend(h.join().unwrap());
            }
            topo.crash(&mut rng);
            q.recover(topo.primary());
            assert!(
                q.draining_info(0).is_none(),
                "seed {seed} cycle {cycle}: recovery left two plans"
            );
        }
        returned.extend(drain(&q, 0));
        let n = returned.len();
        returned.sort_unstable();
        returned.dedup();
        assert_eq!(
            returned.len(),
            n,
            "seed {seed}: duplicate delivery across resize crash cycles"
        );
    }
}

/// Back-to-back resizes with a crash between them: the plan log's two
/// record slots alternate; epochs stay monotone and coherent.
#[test]
fn consecutive_resizes_across_crashes_keep_log_coherent() {
    install_quiet_crash_hook();
    let (topo, q) = mk(1, 2, 1, 1, 0.5, 0.3, 9);
    let mut rng = Xoshiro256::seed_from(10);
    let mut expect_epoch = 1;
    for (i, k) in [4usize, 3, 8, 2].iter().enumerate() {
        for v in 0..8u64 {
            q.enqueue(0, 100 * i as u64 + v).unwrap();
        }
        assert_eq!(q.resize(0, *k), Ok(expect_epoch + 1));
        expect_epoch += 1;
        topo.crash(&mut rng);
        q.recover(topo.primary());
        assert_eq!(q.plan_epoch(), expect_epoch, "epochs must stay monotone");
        assert_eq!(q.shard_count(), *k);
        assert!(q.draining_info(0).is_none());
        let mut got = drain(&q, 1);
        let n = got.len();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), n, "resize {i}: duplicates");
        assert_eq!(
            got,
            (0..8).map(|v| 100 * i as u64 + v).collect::<Vec<u64>>(),
            "resize {i}: per-op durable items lost"
        );
    }
}
