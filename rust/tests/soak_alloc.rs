//! Long-haul allocator soak: resize + churn + crash cycles must reach a
//! memory plateau. The bump cursor (`used_words`) only ever grows, so
//! the only way repeated cycles stay bounded is for the palloc tier to
//! keep feeding retired stripes, recycled ring nodes and reused batch
//! logs back into circulation — across crashes, whose conservative
//! rebuilds are allowed to leak a little (non-durable frees) but never
//! to compound.
//!
//! `PERSIQ_SOAK_CYCLES` overrides the cycle count (default 20) so CI can
//! run a quick smoke pass while the full soak stays the local default.

use persiq::pmem::crash::{install_quiet_crash_hook, run_guarded};
use persiq::pmem::{CostModel, PmemConfig, Topology};
use persiq::queues::blockfifo::BlockFifo;
use persiq::queues::sharded::ShardedQueue;
use persiq::queues::{ConcurrentQueue, PersistentQueue, QueueConfig};
use persiq::util::rng::Xoshiro256;

fn cycles() -> usize {
    std::env::var("PERSIQ_SOAK_CYCLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&c| c >= 1)
        .unwrap_or(20)
}

fn topo(seed: u64) -> Topology {
    Topology::single(PmemConfig {
        capacity_words: 1 << 22,
        cost: CostModel::zero(),
        evict_prob: 0.25,
        pending_flush_prob: 0.5,
        seed,
    })
}

/// The tentpole soak: ≥ 20 cycles of {online resize, node-churning
/// workload, crash, recovery, drain}, alternating 4 ↔ 8 stripes on a
/// tiny ring so every cycle allocates stripes, nodes and log space. The
/// arena high-water mark after the full run must stay within 2× the
/// first cycle's peak — i.e. cycles 2..n run (almost) entirely on
/// recycled memory.
#[test]
fn resize_churn_crash_cycles_plateau_within_2x_first_peak() {
    install_quiet_crash_hook();
    let t = topo(61);
    let q = ShardedQueue::new_perlcrq(
        &t,
        1,
        QueueConfig { shards: 4, ring_size: 8, batch: 4, batch_deq: 4, ..Default::default() },
    )
    .unwrap();
    let mut rng = Xoshiro256::seed_from(62);
    let mut first_peak = 0usize;
    for cycle in 0..cycles() {
        let new_k = if cycle % 2 == 0 { 8 } else { 4 };
        q.resize(0, new_k).unwrap();
        if cycle % 4 == 3 {
            // Every fourth cycle crashes mid-churn (countdown), landing
            // inside allocation/retirement machinery.
            t.arm_crash_after(3_000 + rng.next_below(3_000));
            let _ = run_guarded(|| {
                for v in 0..800u64 {
                    q.enqueue(0, v).unwrap();
                    if v % 2 == 0 {
                        let _ = q.dequeue(0).unwrap();
                    }
                }
            });
        } else {
            for v in 0..800u64 {
                q.enqueue(0, v).unwrap();
                if v % 2 == 0 {
                    let _ = q.dequeue(0).unwrap();
                }
            }
            q.flush(0);
        }
        t.crash(&mut rng);
        q.recover(t.primary());
        while q.dequeue(0).unwrap().is_some() {}
        if cycle == 0 {
            first_peak = t.primary().used_words();
            assert!(first_peak > 0);
        }
    }
    let final_water = t.primary().used_words();
    assert!(
        final_water <= 2 * first_peak,
        "arena high-water {final_water} exceeds 2x the first-cycle peak {first_peak}: \
         the allocator is leaking across cycles"
    );
    assert!(
        t.primary().palloc().recycled_total() > 0,
        "the soak must actually run on recycled segments"
    );
}

/// Blockfifo leg: with recycling on, a workload far beyond the raw
/// block capacity runs clean across repeated crash/recovery cycles (the
/// recycle pool is rebuilt from durable CONSUMED headers each time).
#[test]
fn blockfifo_soak_runs_past_raw_capacity_across_crashes() {
    install_quiet_crash_hook();
    let t = topo(63);
    // 2 lanes x 8 blocks x 4 entries = 64 raw slots.
    let q = BlockFifo::new(
        &t,
        1,
        QueueConfig { shards: 2, block: 4, ring_size: 8, ..Default::default() },
        false,
    )
    .unwrap();
    let mut rng = Xoshiro256::seed_from(64);
    let rounds = cycles().max(2);
    let mut delivered = 0u64;
    for round in 0..rounds as u64 {
        let base = round * 40;
        for v in base..base + 40 {
            q.enqueue(0, v).unwrap();
        }
        let mut out = Vec::new();
        while let Some(v) = q.dequeue(0).unwrap() {
            out.push(v);
        }
        out.sort_unstable();
        assert_eq!(out, (base..base + 40).collect::<Vec<u64>>(), "round {round}");
        delivered += out.len() as u64;
        if round % 5 == 4 {
            q.quiesce();
            t.crash(&mut rng);
            q.recover(t.primary());
            assert_eq!(q.dequeue(0).unwrap(), None, "drained queue must recover empty");
        }
    }
    assert!(delivered > 64, "soak must push past the 64-slot raw capacity");
}
