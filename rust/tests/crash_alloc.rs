//! Crash-countdown sweep through `pmem::palloc`'s free, recycle and
//! magazine-refill paths — the allocator's two recovery guarantees,
//! checked at every crash point a scripted churn workload can produce:
//!
//! * **No double allocation.** After a crash, no segment the rebuild
//!   hands out may overlap a segment whose header is durably `LIVE`
//!   (the conservative proxy for "might still be durably reachable").
//! * **No durably-freed-segment loss.** A free whose `FREE` header flip
//!   was covered by a completed `psync` must survive the crash: its
//!   header still reads `FREE` and the rebuild puts it back on a
//!   freelist instead of leaking it.
//!
//! The sweep arms the step countdown at every offset of a fixed script
//! (single pool and two-pool topologies), so crashes land between a
//! free's store and its pwb, inside magazine refills, mid-psync, and so
//! on. A queue-level sweep then drives the same machinery through
//! PerLCRQ node recycling and checks end-to-end conservation.

use std::collections::HashMap;

use persiq::pmem::crash::{install_quiet_crash_hook, run_guarded};
use persiq::pmem::{CostModel, PAddr, PmemConfig, PmemPool, Topology, WORDS_PER_LINE};
use persiq::queues::sharded::ShardedQueue;
use persiq::queues::{ConcurrentQueue, PersistentQueue, QueueConfig};
use persiq::util::rng::Xoshiro256;

/// On-media segment-header format, mirrored from `pmem::palloc` — this
/// test audits the durable format directly, so it spells the constants
/// out rather than reaching into the module.
const SEG_MAGIC: u64 = 0x9A5E;
const SEG_LIVE: u64 = 1;
const SEG_FREE: u64 = 2;

/// Durable `(lines, state)` of the segment whose user area starts at
/// `user`, if its header carries the palloc magic.
fn hdr_info(pool: &PmemPool, user: u32) -> Option<(usize, u64)> {
    let w = pool.peek(PAddr(user - WORDS_PER_LINE as u32));
    (w >> 48 == SEG_MAGIC).then_some((((w >> 32) & 0xFFFF) as usize, w & 0xFFFF))
}

fn cfg(seed: u64) -> PmemConfig {
    PmemConfig {
        capacity_words: 1 << 18,
        cost: CostModel::zero(),
        evict_prob: 0.3,
        pending_flush_prob: 0.5,
        seed,
    }
}

/// Test-side durability ledger for one pool's scripted churn. Every
/// mutation happens only *after* the corresponding pmem call returned,
/// so a mid-call crash leaves the ledger strictly conservative.
#[derive(Default)]
struct Ledger {
    /// user addr -> segment lines, for every address ever handed out.
    ever: HashMap<u32, usize>,
    /// Allocated and not yet freed (script-visible holds).
    held: Vec<u32>,
    /// Freed, but no psync has completed since.
    pending_free: Vec<u32>,
    /// Freed, and a later psync completed: the FREE flip is durable.
    durable_free: Vec<u32>,
}

impl Ledger {
    fn on_alloc(&mut self, a: PAddr, lines: usize) {
        self.ever.insert(a.0, lines);
        self.durable_free.retain(|&x| x != a.0);
        self.pending_free.retain(|&x| x != a.0);
        self.held.push(a.0);
    }

    fn on_psync(&mut self) {
        self.durable_free.append(&mut self.pending_free);
    }
}

/// One churn pass on `pool` under thread `tid`: interleaved allocs of
/// two size classes, frees, and periodic psyncs, with a 2-slot magazine
/// so refills and spills hit the shared freelist constantly.
fn churn(pool: &PmemPool, tid: usize, led: &mut Ledger) {
    for i in 0..160usize {
        let lines = if i % 5 == 4 { 2 } else { 4 };
        let a = pool.palloc_alloc(tid, lines).expect("arena exhausted mid-script");
        led.on_alloc(a, lines);
        if i % 2 == 1 {
            let victim = led.held.remove(0);
            pool.palloc_free(tid, PAddr(victim));
            led.pending_free.push(victim);
        }
        if i % 7 == 0 {
            pool.psync(tid);
            led.on_psync();
        }
    }
    pool.psync(tid);
    led.on_psync();
}

/// Post-crash audit of one pool against its ledger (crash already
/// normalized: live == shadow, volatile freelists rebuilt).
fn audit(pool: &PmemPool, led: &Ledger) {
    // No durably-freed-segment loss: the durable FREE flips survived …
    for &a in &led.durable_free {
        let (_, state) = hdr_info(pool, a).expect("durably-freed header lost its magic");
        assert_eq!(state, SEG_FREE, "durably-freed segment at {a} rolled back to state {state}");
    }
    // … and the rebuild put each one back on its class freelist (the
    // counts can exceed the ledger's: frees whose pwb happened to drain
    // at the crash cut are recovered too).
    for lines in [2usize, 4] {
        let durable = led
            .durable_free
            .iter()
            .filter(|a| led.ever.get(a) == Some(&lines))
            .count();
        assert!(
            pool.palloc().free_count(lines) >= durable,
            "rebuild recovered {} class-{lines} segments, ledger proves {durable}",
            pool.palloc().free_count(lines)
        );
    }
    // No double allocation: nothing the rebuilt allocator hands out may
    // overlap a durably-LIVE segment (header line included).
    let live: Vec<(u32, u32)> = led
        .ever
        .iter()
        .filter(|(&a, _)| matches!(hdr_info(pool, a), Some((_, s)) if s == SEG_LIVE))
        .map(|(&a, &lines)| (a - WORDS_PER_LINE as u32, a + (lines * WORDS_PER_LINE) as u32))
        .collect();
    let mut fresh: Vec<(u32, u32)> = Vec::new();
    for _ in 0..16 {
        let a = pool.palloc_alloc(0, 4).expect("post-crash arena exhausted");
        let range = (a.0 - WORDS_PER_LINE as u32, a.0 + (4 * WORDS_PER_LINE) as u32);
        for &(s, e) in live.iter().chain(fresh.iter()) {
            assert!(
                range.1 <= s || e <= range.0,
                "post-crash alloc {range:?} overlaps live/previous segment ({s}, {e})"
            );
        }
        fresh.push(range);
    }
    pool.psync(0);
}

/// Single-pool sweep: every third step offset across the whole script.
#[test]
fn countdown_sweep_single_pool_never_double_allocates() {
    install_quiet_crash_hook();
    let mut rng = Xoshiro256::seed_from(41);
    for steps in (1..=420u64).step_by(3) {
        let pool = PmemPool::new(cfg(1000 + steps));
        pool.palloc().set_magazine_cap(2);
        let mut led = Ledger::default();
        pool.arm_crash_after(steps);
        let _ = run_guarded(|| churn(&pool, 0, &mut led));
        pool.crash(&mut rng);
        audit(&pool, &led);
    }
}

/// Two-pool topology: the countdown cut lands at one machine-wide
/// point, interrupting interleaved churn on both pools; each pool's
/// rebuild must satisfy both guarantees independently.
#[test]
fn countdown_sweep_two_pools_recover_independently() {
    install_quiet_crash_hook();
    let mut rng = Xoshiro256::seed_from(43);
    for steps in (1..=840u64).step_by(13) {
        let topo = Topology::new(cfg(2000 + steps), 2);
        let mut leds = [Ledger::default(), Ledger::default()];
        for p in topo.pools() {
            p.palloc().set_magazine_cap(2);
        }
        topo.arm_crash_after(steps);
        let _ = run_guarded(|| {
            // Alternate pools at fine grain so the cut can land with
            // either pool's free/refill half-done.
            for _round in 0..4 {
                for (i, p) in topo.pools().iter().enumerate() {
                    churn(p, i, &mut leds[i]);
                }
            }
        });
        topo.crash(&mut rng);
        for (i, p) in topo.pools().iter().enumerate() {
            audit(p, &leds[i]);
        }
    }
}

/// Queue-level sweep: a 4-slot ring forces PerLCRQ through node
/// allocation, limbo retirement and recycling on nearly every op; the
/// countdown sweeps crash points across that machinery and the checker
/// is end-to-end conservation (no duplicate delivery, ever).
#[test]
fn countdown_sweep_through_queue_recycling_conserves_items() {
    install_quiet_crash_hook();
    let mut rng = Xoshiro256::seed_from(47);
    let mut total_recycled = 0u64;
    for (cycle, steps) in (100..=3000u64).step_by(271).enumerate() {
        let topo = Topology::single(cfg(3000 + steps));
        let q = ShardedQueue::new_perlcrq(
            &topo,
            1,
            QueueConfig { shards: 2, ring_size: 4, ..Default::default() },
        )
        .unwrap();
        let mut returned: Vec<u64> = Vec::new();
        let mut enq_started = 0u64;
        topo.arm_crash_after(steps);
        let _ = run_guarded(|| {
            for i in 0..2000u64 {
                q.enqueue(0, i).unwrap();
                enq_started = i + 1;
                if i % 2 == 0 {
                    if let Some(v) = q.dequeue(0).unwrap() {
                        returned.push(v);
                    }
                }
            }
        });
        topo.crash(&mut rng);
        q.recover(topo.primary());
        while let Ok(Some(v)) = q.dequeue(0) {
            returned.push(v);
        }
        let n = returned.len();
        returned.sort_unstable();
        returned.dedup();
        assert_eq!(returned.len(), n, "duplicate delivery in cycle {cycle}");
        assert!(
            returned.iter().all(|&v| v < enq_started),
            "delivered an item that was never enqueued (cycle {cycle})"
        );
        total_recycled += topo.primary().palloc().recycled_total();
    }
    assert!(total_recycled > 0, "the sweep must actually exercise segment recycling");
}
