//! The psync-by-site ledger checks: the paper's persistence-cost
//! accounting (`1/B + 1/K` psyncs per op pair in steady state, `new_k +
//! 3` per re-shard transition) asserted against `persiq::obs`'s site
//! attribution — plus the golden-schema check for the JSONL event trace.
//!
//! These tests pin the *attribution*, not just the totals the older
//! integration tests bound: a steady-state run must charge every psync
//! to `BatchFlush`/`DeqFlush` (zero to `Resize`/`Recovery`), a resize
//! must cost exactly `new_k` `Resize` + 3 `PlanCommit` psyncs, and
//! recovery must capture all of its traffic — including the flushes of
//! its forward drain — under `Recovery`.

use persiq::obs::{self, ObsSite, SiteLedger};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::{CostModel, PmemConfig, Topology};
use persiq::queues::sharded::ShardedQueue;
use persiq::queues::{ConcurrentQueue, PersistentQueue, QueueConfig};
use persiq::util::rng::Xoshiro256;

fn mk(nthreads: usize, shards: usize, batch: usize, batch_deq: usize) -> (Topology, ShardedQueue) {
    let topo = Topology::single(PmemConfig {
        capacity_words: 1 << 22,
        cost: CostModel::zero(),
        evict_prob: 0.25,
        pending_flush_prob: 0.5,
        seed: 71,
    });
    let q = ShardedQueue::new_perlcrq(
        &topo,
        nthreads,
        QueueConfig { shards, batch, batch_deq, ring_size: 1 << 10, ..Default::default() },
    )
    .unwrap();
    (topo, q)
}

fn delta(after: &SiteLedger, before: &SiteLedger, site: ObsSite) -> u64 {
    after.psyncs_at(site) - before.psyncs_at(site)
}

/// Steady state, single pool, B = K = 8: every psync is a batch-seal
/// group commit — `n/B` to `BatchFlush`, `n/K` to `DeqFlush`, exactly 0
/// anywhere else (construction aside, which is all `Setup`).
#[test]
fn steady_state_psyncs_attribute_to_flush_sites_only() {
    let (b, k, n) = (8u64, 8u64, 512u64);
    let (topo, q) = mk(1, 4, b as usize, k as usize);
    let setup = topo.site_ledger();
    assert!(setup.psyncs_at(ObsSite::Setup) > 0, "construction commits are Setup traffic");
    assert_eq!(setup.psyncs_at(ObsSite::BatchFlush), 0);

    for v in 0..n {
        q.enqueue(0, v).unwrap();
    }
    for _ in 0..n {
        assert!(q.dequeue(0).unwrap().is_some());
    }

    let l = topo.site_ledger();
    assert_eq!(l.psyncs_at(ObsSite::BatchFlush), n / b, "one group commit per sealed batch");
    assert_eq!(l.psyncs_at(ObsSite::DeqFlush), n / k, "one group commit per sealed deq log");
    assert_eq!(l.psyncs_at(ObsSite::Op), 0, "batched mode defers every per-op psync");
    assert_eq!(l.psyncs_at(ObsSite::Resize), 0, "steady state must not pay resize psyncs");
    assert_eq!(l.psyncs_at(ObsSite::PlanCommit), 0);
    assert_eq!(l.psyncs_at(ObsSite::Recovery), 0);
    assert_eq!(l.psyncs_at(ObsSite::BrokerAck), 0);
    assert_eq!(l.psyncs_at(ObsSite::Alloc), 0, "allocator durability must piggyback");

    // The paper's headline bound, per completed enqueue+dequeue pair.
    let steady = l.psyncs_at(ObsSite::BatchFlush) + l.psyncs_at(ObsSite::DeqFlush);
    let per_pair = steady as f64 / n as f64;
    assert!(
        per_pair <= 1.0 / b as f64 + 1.0 / k as f64 + 1e-9,
        "steady-state psyncs/op-pair {per_pair} exceeds 1/B + 1/K"
    );

    // The ledger is a partition of the aggregate counter: no psync may
    // escape attribution.
    assert_eq!(l.total_psyncs(), topo.stats_total().psyncs);
    assert_eq!(l.total_pwbs(), topo.stats_total().pwbs);
}

/// A quiescent resize costs exactly `new_k` fresh-stripe psyncs
/// (`Resize`) plus 3 plan-log commits (`PlanCommit`: record, freeze,
/// retire) — and nothing on the steady-state sites.
#[test]
fn resize_costs_new_k_resize_plus_three_plan_commit_psyncs() {
    let new_k = 8usize;
    let (topo, q) = mk(1, 4, 8, 8);
    let before = topo.site_ledger();
    q.resize(0, new_k).unwrap();
    let after = topo.site_ledger();

    assert_eq!(
        delta(&after, &before, ObsSite::Resize),
        new_k as u64,
        "one root psync per fresh stripe"
    );
    assert_eq!(
        delta(&after, &before, ObsSite::PlanCommit),
        3,
        "record + freeze + retire are the transition's plan commits"
    );
    assert_eq!(delta(&after, &before, ObsSite::BatchFlush), 0);
    assert_eq!(delta(&after, &before, ObsSite::DeqFlush), 0);
    assert_eq!(delta(&after, &before, ObsSite::Op), 0);
    assert_eq!(q.plan_epoch(), 2, "the grown plan must be active");

    // Steady state after the transition: back to flush-site-only psyncs.
    let resumed = topo.site_ledger();
    for v in 0..64u64 {
        q.enqueue(0, v).unwrap();
    }
    for _ in 0..64 {
        assert!(q.dequeue(0).unwrap().is_some());
    }
    let l = topo.site_ledger();
    assert_eq!(delta(&l, &resumed, ObsSite::Resize), 0);
    assert_eq!(delta(&l, &resumed, ObsSite::PlanCommit), 0);
    assert!(delta(&l, &resumed, ObsSite::BatchFlush) > 0);
}

/// Epoch pinning is volatile-only: a burst of hot-path plan accesses —
/// pure pinned reads plus full enqueue/dequeue pin cycles — adds
/// **zero** psyncs and pwbs beyond the group-commit budget the
/// lock-based hot path paid. The pin counters prove the traffic really
/// ran through the epoch protocol rather than around it.
#[test]
fn epoch_pin_unpin_adds_zero_psyncs() {
    let (b, k, n) = (8u64, 8u64, 256u64);
    let (topo, q) = mk(1, 4, b as usize, k as usize);
    let before = topo.site_ledger();
    let pwbs_before = topo.stats_total().pwbs;

    // Pure plan reads: pin, deref, unpin — no persistence traffic.
    for _ in 0..1_000 {
        assert!(q.draining_info(0).is_none());
        assert_eq!(q.plan_epoch(), 1);
    }
    let mid = topo.site_ledger();
    assert_eq!(mid.total_psyncs(), before.total_psyncs(), "pinned reads must not psync");
    assert_eq!(topo.stats_total().pwbs, pwbs_before, "pinned reads must not pwb");

    // Operations pin too; their psyncs stay exactly the group-commit
    // budget — the pin protocol contributes nothing.
    for v in 0..n {
        q.enqueue(0, v).unwrap();
    }
    for _ in 0..n {
        assert!(q.dequeue(0).unwrap().is_some());
    }
    let l = topo.site_ledger();
    assert_eq!(delta(&l, &before, ObsSite::BatchFlush), n / b);
    assert_eq!(delta(&l, &before, ObsSite::DeqFlush), n / k);
    assert_eq!(
        l.total_psyncs() - before.total_psyncs(),
        n / b + n / k,
        "pin/unpin cycles added psyncs"
    );

    // The traffic above really was epoch-pinned.
    let fams = q.metric_families(0);
    let count = |name: &str| {
        fams.iter()
            .find(|f| f.name == name)
            .unwrap_or_else(|| panic!("missing family {name}"))
            .samples[0]
            .value
    };
    let pins = count("persiq_epoch_pins_total");
    let unpins = count("persiq_epoch_unpins_total");
    assert!(pins >= (1_000 + 2 * n) as f64, "expected a pin per access, saw {pins}");
    assert_eq!(pins, unpins, "every pin must have been released");
    assert_eq!(count("persiq_epoch_plan_flips_total"), 0.0, "no flip without a resize");
}

/// Allocator accounting under real node churn: a tiny ring forces the
/// workload through node allocation, retirement and recycling, so the
/// `Alloc` site carries traffic — and all of it is pwb-only. Segment
/// state flips become durable by riding psyncs the queue already pays
/// for (`BatchFlush`/`DeqFlush` group commits); a psync at `Alloc`
/// would mean the allocator broke the paper's `1/B + 1/K` budget.
#[test]
fn allocator_traffic_is_pwb_only_and_attributed_to_alloc() {
    let topo = Topology::single(PmemConfig {
        capacity_words: 1 << 22,
        cost: CostModel::zero(),
        evict_prob: 0.25,
        pending_flush_prob: 0.5,
        seed: 71,
    });
    let q = ShardedQueue::new_perlcrq(
        &topo,
        1,
        QueueConfig { shards: 2, batch: 8, batch_deq: 8, ring_size: 4, ..Default::default() },
    )
    .unwrap();
    let before = topo.site_ledger();
    for round in 0..16u64 {
        for v in 0..64u64 {
            q.enqueue(0, round * 64 + v).unwrap();
        }
        for _ in 0..64 {
            assert!(q.dequeue(0).unwrap().is_some());
        }
    }
    let l = topo.site_ledger();
    assert!(
        l.pwbs_at(ObsSite::Alloc) > before.pwbs_at(ObsSite::Alloc),
        "node churn on a 4-slot ring must run through the allocator"
    );
    assert_eq!(l.psyncs_at(ObsSite::Alloc), 0, "allocator psyncs must be zero, always");
    // Attribution stays a partition of the aggregate counters.
    assert_eq!(l.total_psyncs(), topo.stats_total().psyncs);
    assert_eq!(l.total_pwbs(), topo.stats_total().pwbs);
}

/// Recovery charges every psync — shard recovery, reconciliation, and
/// the forward drain's internal flushes (ambient-scope precedence) — to
/// `Recovery`, never to the steady-state sites.
#[test]
fn recovery_psyncs_attribute_to_recovery_not_flush_sites() {
    install_quiet_crash_hook();
    let (topo, q) = mk(1, 4, 8, 8);
    for v in 0..64u64 {
        q.enqueue(0, v).unwrap();
    }
    q.flush(0);
    let mut rng = Xoshiro256::seed_from(5);
    topo.crash(&mut rng);

    let before = topo.site_ledger();
    q.recover(topo.primary());
    let after = topo.site_ledger();

    assert!(
        delta(&after, &before, ObsSite::Recovery) > 0,
        "recovery's reconciliation psyncs must be attributed"
    );
    assert_eq!(
        delta(&after, &before, ObsSite::BatchFlush),
        0,
        "recovery-internal flushes must not masquerade as steady-state batch seals"
    );
    assert_eq!(delta(&after, &before, ObsSite::DeqFlush), 0);
    assert_eq!(delta(&after, &before, ObsSite::Op), 0);

    // The recovered queue still serves its contents.
    let mut got = Vec::new();
    while let Ok(Some(v)) = q.dequeue(0) {
        got.push(v);
    }
    got.sort_unstable();
    assert_eq!(got, (0..64).collect::<Vec<u64>>());
}

/// The exposition layer renders every family of the stack into
/// parseable Prometheus text with the ledger as labelled counters.
#[test]
fn exposition_renders_sharded_and_ledger_families() {
    let (topo, q) = mk(1, 4, 8, 8);
    for v in 0..32u64 {
        q.enqueue(0, v).unwrap();
    }
    q.flush(0);
    let mut fams = topo.metric_families();
    fams.extend(q.metric_families(0));
    fams.extend(obs::ledger_families(&topo.site_ledger()));
    let text = obs::render(&fams);
    for needle in [
        "# TYPE persiq_pmem_psyncs_total counter",
        "# TYPE persiq_sharded_plan_epoch gauge",
        "# TYPE persiq_pmem_psyncs_by_site_total counter",
        "persiq_pmem_psyncs_by_site_total{site=\"BatchFlush\"}",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    // Prometheus text invariants: every non-comment line is
    // `name{labels} value` with a parseable float value.
    for line in text.lines() {
        if line.starts_with('#') {
            continue;
        }
        let (_, value) = line.rsplit_once(' ').expect("sample lines are name value");
        assert!(value.parse::<f64>().is_ok(), "unparseable sample value in {line:?}");
    }
}

fn mk_blockfifo(
    nthreads: usize,
    shards: usize,
    block: usize,
) -> (Topology, persiq::queues::blockfifo::BlockFifo) {
    let topo = Topology::single(PmemConfig {
        capacity_words: 1 << 22,
        cost: CostModel::zero(),
        evict_prob: 0.25,
        pending_flush_prob: 0.5,
        seed: 71,
    });
    let q = persiq::queues::blockfifo::BlockFifo::new(
        &topo,
        nthreads,
        QueueConfig { shards, block, ring_size: 1 << 10, ..Default::default() },
        false,
    )
    .unwrap();
    (topo, q)
}

/// Blockfifo's whole persistence budget is block-granular: exactly one
/// `BatchFlush` psync per sealed block of `B` enqueues and one `DeqFlush`
/// psync per claimed block of `B` dequeues (the retire pwb rides the next
/// claim's psync), with zero leakage to `Op`/`Resize`/`Recovery` in
/// steady state — and zero `Setup` psyncs at construction (fresh
/// all-zeroes lines are already valid `FREE` headers).
#[test]
fn blockfifo_psyncs_amortize_to_one_per_block_per_side() {
    let (b, m) = (8u64, 16u64);
    let n = b * m;
    let (topo, q) = mk_blockfifo(1, 1, b as usize);
    assert_eq!(topo.site_ledger().total_psyncs(), 0, "zero-initialization construction");

    for v in 0..n {
        q.enqueue(0, v).unwrap();
    }
    let l = topo.site_ledger();
    assert_eq!(l.psyncs_at(ObsSite::BatchFlush), m, "one seal psync per claimed block");
    assert_eq!(l.psyncs_at(ObsSite::DeqFlush), 0);

    for _ in 0..n {
        assert!(q.dequeue(0).unwrap().is_some());
    }
    let l = topo.site_ledger();
    assert_eq!(l.psyncs_at(ObsSite::DeqFlush), m, "one claim psync per drained block");
    assert_eq!(l.psyncs_at(ObsSite::Op), 0, "no per-op psyncs anywhere on the hot path");
    assert_eq!(l.psyncs_at(ObsSite::Resize), 0);
    assert_eq!(l.psyncs_at(ObsSite::Recovery), 0);
    assert_eq!(l.psyncs_at(ObsSite::PlanCommit), 0);
    assert_eq!(l.psyncs_at(ObsSite::BrokerAck), 0);
    assert_eq!(l.psyncs_at(ObsSite::Alloc), 0, "block recycling must not psync on its own");

    // The headline amortization, per completed enqueue+dequeue pair.
    let per_pair = l.total_psyncs() as f64 / n as f64;
    assert!(
        per_pair <= 2.0 / b as f64 + 1e-9,
        "blockfifo psyncs/op-pair {per_pair} exceeds 2/B"
    );

    // Partition: every psync and pwb is attributed to some site.
    assert_eq!(l.total_psyncs(), topo.stats_total().psyncs);
    assert_eq!(l.total_pwbs(), topo.stats_total().pwbs);
}

/// Blockfifo recovery traffic lands on `Recovery` only, and the
/// steady-state sites come back clean afterwards.
#[test]
fn blockfifo_recovery_psyncs_attribute_to_recovery_only() {
    install_quiet_crash_hook();
    let (topo, q) = mk_blockfifo(1, 2, 8);
    for v in 0..48u64 {
        q.enqueue(0, v).unwrap();
    }
    q.quiesce();
    let mut rng = Xoshiro256::seed_from(5);
    topo.crash(&mut rng);

    let before = topo.site_ledger();
    q.recover(topo.primary());
    let after = topo.site_ledger();
    assert!(
        delta(&after, &before, ObsSite::Recovery) > 0,
        "the per-lane recovery commits must be attributed"
    );
    assert_eq!(delta(&after, &before, ObsSite::BatchFlush), 0);
    assert_eq!(delta(&after, &before, ObsSite::DeqFlush), 0);
    assert_eq!(delta(&after, &before, ObsSite::Op), 0);

    // Post-recovery steady state: block-granular flush sites only.
    let resumed = topo.site_ledger();
    for v in 0..16u64 {
        q.enqueue(0, v).unwrap();
    }
    let l = topo.site_ledger();
    assert_eq!(delta(&l, &resumed, ObsSite::BatchFlush), 2, "16 enqueues = 2 sealed blocks");
    assert_eq!(delta(&l, &resumed, ObsSite::Recovery), 0);

    // The recovered queue still serves everything quiesce published.
    let mut got = Vec::new();
    while let Ok(Some(v)) = q.dequeue(0) {
        got.push(v);
    }
    got.sort_unstable();
    let mut expect: Vec<u64> = (0..48).chain(0..16).collect();
    expect.sort_unstable();
    assert_eq!(got, expect);
}

/// The persistent flight recorder piggybacks on the queue's own group
/// commits: with the recorder armed (the default) the psync ledger is
/// **identical, site by site**, to a recorder-disabled run of the same
/// workload — steady-state flushes, a full resize, and a tail flush —
/// while the armed run demonstrably captured the history (certified
/// events in the ring, i.e. its seals became durable without a single
/// psync of their own). The recorder's only traffic is pwbs folded into
/// drains the queue already pays for.
#[test]
fn flight_recorder_adds_zero_psyncs_at_every_site() {
    use persiq::obs::flight;

    let n = 256u64;
    let run = || {
        let (topo, q) = mk(1, 4, 8, 8);
        for v in 0..n {
            q.enqueue(0, v).unwrap();
        }
        for _ in 0..n / 2 {
            assert!(q.dequeue(0).unwrap().is_some());
        }
        q.resize(0, 8).unwrap();
        q.flush(0);
        topo
    };

    flight::set_enabled(true);
    let topo_on = run();
    let on = topo_on.site_ledger();

    // The armed run really recorded: tid 0's ring holds events, and a
    // flush seal is already durable — certified by piggybacked drains.
    let scans = flight::scan(&topo_on);
    assert!(scans[0].present, "pool must carve a recorder region");
    let ring = scans[0].rings.iter().find(|r| r.tid == 0).expect("tid 0 recorded");
    assert!(!ring.events.is_empty(), "armed recorder must capture the workload");
    assert!(ring.last_certified_seq > 0, "flush seals must ride the existing psyncs");

    flight::set_enabled(false);
    let topo_off = run();
    let off = topo_off.site_ledger();
    flight::set_enabled(true);

    let disarmed_events: usize =
        flight::scan(&topo_off).iter().flat_map(|p| &p.rings).map(|r| r.events.len()).sum();
    assert_eq!(disarmed_events, 0, "disarmed recorder must write nothing");

    for site in [
        ObsSite::Setup,
        ObsSite::Op,
        ObsSite::BatchFlush,
        ObsSite::DeqFlush,
        ObsSite::Resize,
        ObsSite::PlanCommit,
        ObsSite::Recovery,
        ObsSite::BrokerAck,
        ObsSite::Alloc,
    ] {
        assert_eq!(
            on.psyncs_at(site),
            off.psyncs_at(site),
            "recorder changed the {site:?} psync budget"
        );
    }
    assert_eq!(on.total_psyncs(), off.total_psyncs(), "recorder added psyncs");
    assert!(
        topo_on.stats_total().pwbs >= topo_off.stats_total().pwbs,
        "the recorder's cost is pwb-only, so the armed run can only add pwbs"
    );
    // The known exact budget still holds with the recorder armed.
    assert_eq!(on.psyncs_at(ObsSite::BatchFlush), n / 8);
    assert_eq!(on.psyncs_at(ObsSite::DeqFlush), n / 2 / 8);
    assert_eq!(on.psyncs_at(ObsSite::Resize), 8);
    assert_eq!(on.psyncs_at(ObsSite::PlanCommit), 3);
}

/// Golden-schema check for the JSONL trace: every line carries
/// `ts`/`tid`/`type`, and each event type carries its required keys.
/// Tracing state is process-global, so this single test owns the whole
/// arm → workload → flush lifecycle.
#[test]
fn trace_jsonl_golden_schema() {
    let path =
        std::env::temp_dir().join(format!("persiq_obs_ledger_trace_{}.jsonl", std::process::id()));
    obs::trace::start(&path);

    let (topo, q) = mk(1, 4, 8, 8);
    for v in 0..64u64 {
        q.enqueue(0, v).unwrap();
    }
    for _ in 0..32 {
        assert!(q.dequeue(0).unwrap().is_some());
    }
    q.resize(0, 8).unwrap();
    q.flush(0);
    let _ = topo;

    let rep = obs::trace::stop().unwrap().expect("trace was armed");
    let text = std::fs::read_to_string(&rep.path).unwrap();
    let _ = std::fs::remove_file(&rep.path);
    assert!(rep.written > 0, "the workload must have emitted events");

    let required: &[(&str, &[&str])] = &[
        ("psync", &["\"site\":", "\"pool\":", "\"drained\":"]),
        ("batch_seal", &["\"kind\":", "\"n\":", "\"pools\":"]),
        ("span", &["\"name\":", "\"start\":", "\"dur\":"]),
        ("event", &["\"name\":"]),
        ("future", &["\"stage\":", "\"idx\":"]),
    ];
    let mut last_ts = 0u64;
    let mut seen_psync = false;
    let mut seen_seal = false;
    for line in text.lines() {
        assert!(
            line.starts_with("{\"ts\":") && line.ends_with('}'),
            "line must be a ts-led JSON object: {line:?}"
        );
        let ts: u64 = line["{\"ts\":".len()..]
            .chars()
            .take_while(|c| c.is_ascii_digit())
            .collect::<String>()
            .parse()
            .unwrap();
        assert!(ts >= last_ts, "merged trace must be ts-sorted");
        last_ts = ts;
        assert!(line.contains("\"tid\":"), "missing tid: {line:?}");
        let typ = required
            .iter()
            .find(|(t, _)| line.contains(&format!("\"type\":\"{t}\"")))
            .unwrap_or_else(|| panic!("unknown event type in {line:?}"));
        for key in typ.1 {
            assert!(line.contains(key), "{} event missing {key}: {line:?}", typ.0);
        }
        seen_psync |= typ.0 == "psync";
        seen_seal |= typ.0 == "batch_seal";
    }
    assert!(seen_psync && seen_seal, "workload must emit psync and batch_seal events");
}
