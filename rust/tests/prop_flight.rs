//! Property tests for the persistent flight recorder: crashes swept
//! through enqueue / dequeue / flush / resize must leave NVM rings whose
//! **certified** event set is consistent with the queue state recovery
//! actually reconstructs:
//!
//! * **A** — a certified-durable `OpEnq` item is never lost: it survives
//!   recovery, was returned to a caller pre-crash, or is certified
//!   durably consumed.
//! * **B** — a certified-durable `OpDeq` item is never redelivered.
//! * **C** — prefix completeness, per epoch: below an epoch's highest
//!   durable flush seal, every same-epoch sequence number is present as
//!   a checksum-valid entry (the only tolerated gap is the seal's
//!   immediate sibling, written after the same psync and lost to the
//!   same cut). The check is epoch-scoped because a post-recovery seal
//!   proves nothing about a *previous* life's open tail — those entries
//!   reverted with the crash even though their seqs sit below it.
//!
//! Workloads are sized well under one ring (64 entries) so the window
//! never wraps — `overwritten == 0` is itself asserted. Scans run after
//! the crash and **before** recovery, exactly as `persiq forensics`
//! does.

use std::collections::{BTreeMap, HashSet};
use std::sync::Arc;

use persiq::obs::flight;
use persiq::pmem::crash::{install_quiet_crash_hook, run_guarded};
use persiq::pmem::{CostModel, PmemConfig, Topology};
use persiq::queues::sharded::ShardedQueue;
use persiq::queues::{ConcurrentQueue, PersistentQueue, QueueConfig};
use persiq::util::rng::Xoshiro256;

fn mk(pools: usize, batch: usize, seed: u64) -> (Topology, Arc<ShardedQueue>) {
    let topo = Topology::new(
        PmemConfig {
            // >= flight::MIN_CAPACITY_WORDS so every pool carves a
            // recorder region.
            capacity_words: 1 << 18,
            cost: CostModel::zero(),
            evict_prob: 0.3,
            pending_flush_prob: 0.5,
            seed,
        },
        pools,
    );
    let cfg =
        QueueConfig { shards: 4, batch, batch_deq: batch, ring_size: 64, ..Default::default() };
    let q = Arc::new(ShardedQueue::new_perlcrq(&topo, 4, cfg).unwrap());
    (topo, q)
}

fn drain(q: &ShardedQueue, tid: usize) -> Vec<u64> {
    let mut out = Vec::new();
    while let Ok(Some(v)) = q.dequeue(tid) {
        out.push(v);
    }
    out
}

/// Invariant C over a pre-recovery scan, plus the no-wrap guard.
fn assert_prefix_complete(scans: &[flight::PoolScan], ctxt: &str) {
    for ps in scans {
        for ring in &ps.rings {
            assert_eq!(
                ring.overwritten, 0,
                "{ctxt}: ring tid {} wrapped — workload oversized for the window",
                ring.tid
            );
            let present: HashSet<u64> = ring.events.iter().map(|e| e.seq).collect();
            // First observed seq per epoch: every same-epoch entry below
            // the epoch's seal is provably durable, so this is a true
            // lower bound on where the epoch's window starts.
            let mut first: BTreeMap<u64, u64> = BTreeMap::new();
            for e in &ring.events {
                let f = first.entry(e.epoch).or_insert(u64::MAX);
                *f = (*f).min(e.seq);
            }
            for (&epoch, &m) in &ring.seal_max {
                // The seal itself is an epoch event, so `first` has it.
                for s in first[&epoch]..=m {
                    assert!(
                        present.contains(&s) || s + 1 == m,
                        "{ctxt}: ring tid {}: seq {s} (epoch {epoch}) missing below \
                         certified seal {m} (events {:?})",
                        ring.tid,
                        ring.events
                    );
                }
            }
        }
    }
}

fn crosscheck(
    scans: &[flight::PoolScan],
    survivors: &HashSet<u64>,
    returned: &HashSet<u64>,
    ctxt: &str,
) {
    let tl = flight::timeline(scans);
    let cc = flight::crosscheck_queue(&tl, survivors, returned);
    assert!(
        cc.pass(),
        "{ctxt}: {} durable enqs, {} durable deqs, violations: {:#?}",
        cc.durable_enqs,
        cc.durable_deqs,
        cc.violations
    );
}

/// Sweep the armed crash countdown through every phase of a mixed
/// workload — batched enqueues, batched dequeues, both flush paths, and
/// a full online resize — on 1- and 2-pool topologies. At every cut the
/// certified flight record must agree with what recovery delivers.
#[test]
fn crash_swept_through_enq_deq_flush_and_resize() {
    install_quiet_crash_hook();
    for pools in [1usize, 2] {
        for j in 1..=160u64 {
            let (topo, q) = mk(pools, 4, 9_000 + j);
            let base = j * 1_000;
            let mut returned_v: Vec<u64> = Vec::new();
            topo.arm_crash_after(j);
            let _ = run_guarded(|| {
                for v in 0..10u64 {
                    q.enqueue(0, base + v).unwrap();
                }
                q.flush_all();
                for _ in 0..5 {
                    if let Ok(Some(v)) = q.dequeue(0) {
                        returned_v.push(v);
                    }
                }
                let _ = q.resize(0, 6);
                for v in 10..16u64 {
                    q.enqueue(0, base + v).unwrap();
                }
                for _ in 0..4 {
                    if let Ok(Some(v)) = q.dequeue(0) {
                        returned_v.push(v);
                    }
                }
                q.flush_all();
            });
            let mut rng = Xoshiro256::seed_from(31 * j);
            topo.crash(&mut rng);
            // Scan the post-crash image BEFORE recovery mutates it.
            let scans = flight::scan(&topo);
            let ctxt = format!("pools={pools} j={j}");
            assert_prefix_complete(&scans, &ctxt);
            q.recover(topo.primary());
            let survivors: HashSet<u64> = drain(&q, 0).into_iter().collect();
            let returned: HashSet<u64> = returned_v.into_iter().collect();
            crosscheck(&scans, &survivors, &returned, &ctxt);
        }
    }
}

/// Two full crash/recover cycles on one queue: the ring carries both
/// epochs, and a seal from the post-recovery epoch must not certify
/// luck-landed advisories from before the crash (epoch-gated
/// certification). `returned` accumulates across cycles so invariant A
/// can account for items consumed in an earlier life.
#[test]
fn seals_never_certify_across_the_crash_epoch() {
    install_quiet_crash_hook();
    for seed in [3u64, 11, 27] {
        let (topo, q) = mk(1, 4, seed);
        let mut rng = Xoshiro256::seed_from(seed * 7);
        let mut returned: HashSet<u64> = HashSet::new();
        for cycle in 0..2u64 {
            let base = (seed * 10 + cycle) * 1_000;
            topo.arm_crash_after(40 + rng.next_below(120));
            let mut mine: Vec<u64> = Vec::new();
            let _ = run_guarded(|| {
                for v in 0..8u64 {
                    q.enqueue(0, base + v).unwrap();
                }
                q.flush_all();
                for _ in 0..4 {
                    if let Ok(Some(v)) = q.dequeue(0) {
                        mine.push(v);
                    }
                }
                q.flush_all();
            });
            returned.extend(mine);
            topo.crash(&mut rng);
            let scans = flight::scan(&topo);
            let ctxt = format!("seed={seed} cycle={cycle}");
            assert_prefix_complete(&scans, &ctxt);
            q.recover(topo.primary());
            let survivors: HashSet<u64> = drain(&q, 0).into_iter().collect();
            crosscheck(&scans, &survivors, &returned, &ctxt);
            // Drained items count as returned for the next cycle's check.
            returned.extend(&survivors);
        }
    }
}

/// Concurrent producers/consumers, crash landing anywhere: per-thread
/// rings scattered across pools must still cross-check. Each thread's
/// values are disjoint so any certified-durable loss or redelivery is
/// attributable.
#[test]
fn concurrent_workload_crosschecks_after_crash() {
    install_quiet_crash_hook();
    for seed in [5u64, 17, 40] {
        for pools in [1usize, 2] {
            let (topo, q) = mk(pools, 4, seed * 100 + pools as u64);
            topo.arm_crash_after(150 + seed * 13);
            let mut hs = Vec::new();
            for tid in 0..4usize {
                let q = Arc::clone(&q);
                hs.push(std::thread::spawn(move || {
                    let mut mine = Vec::new();
                    let _ = run_guarded(|| {
                        let base = 1_000_000 * (tid as u64 + 1);
                        for i in 0..8u64 {
                            q.enqueue(tid, base + i).unwrap();
                            if let Ok(Some(v)) = q.dequeue(tid) {
                                mine.push(v);
                            }
                        }
                        let _ = q.flush(tid);
                    });
                    mine
                }));
            }
            let mut returned: HashSet<u64> = HashSet::new();
            for h in hs {
                returned.extend(h.join().unwrap());
            }
            let mut rng = Xoshiro256::seed_from(seed + 1);
            topo.crash(&mut rng);
            let scans = flight::scan(&topo);
            let ctxt = format!("seed={seed} pools={pools}");
            assert_prefix_complete(&scans, &ctxt);
            q.recover(topo.primary());
            let survivors: HashSet<u64> = drain(&q, 0).into_iter().collect();
            crosscheck(&scans, &survivors, &returned, &ctxt);
        }
    }
}
