//! Figure 9 (beyond the paper) — what asynchronous completion buys:
//! sync-blocking vs sync-batched vs async-overlap throughput over the
//! batch size, on the same sharded queue.
//!
//! The three series model three durability contracts a service can offer:
//!
//! * **sync-blocking** — the caller needs each operation durable before
//!   it proceeds (ack-after-persist). With the sync API that forces
//!   per-op persistence (`batch = 1`): one psync per op, flat over B.
//! * **sync-batched** — group commit (`batch = batch_deq = B`), but the
//!   caller's *return* races durability: cheap, yet a crash can lose the
//!   unflushed window after callers already moved on.
//! * **async** — the completion layer: callers hold futures that resolve
//!   at the flush, getting sync-blocking's contract at sync-batched's
//!   psync cost by overlapping the wait across the in-flight window.
//!
//! Headline claims (checked below): at B ≥ 8 the async path beats
//! sync-blocking by ≥ 1.2× simulated throughput, and its psyncs/op is no
//! worse than the sync batched path (1/B enq + 1/K deq).

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use persiq::harness::bench::{bench_ops, Suite};
use persiq::harness::{run_async_workload, AsyncRunConfig};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::queues::asyncq::AsyncCfg;
use persiq::queues::sharded::ShardedQueue;
use persiq::queues::QueueConfig;

const THREADS: usize = 4;
const SHARDS: usize = 8;

fn async_point(batch: usize, ops: u64) -> (f64, Vec<(String, f64)>) {
    let qcfg = QueueConfig { shards: SHARDS, batch, batch_deq: batch, ..Default::default() };
    // Producers + an equal flusher pool: the queue-operating parallelism
    // matches the sync series' thread count.
    let acfg = AsyncCfg { flush_us: 5_000, depth: batch.max(2), flushers: THREADS };
    let ctx = common::ctx_with(THREADS + acfg.flushers, qcfg.clone());
    let q = Arc::new(
        ShardedQueue::new_perlcrq(&ctx.topo, THREADS + acfg.flushers, qcfg)
            .expect("valid bench config"),
    );
    let rc = AsyncRunConfig {
        producers: THREADS,
        total_ops: ops,
        window: (2 * batch).max(4),
        acfg,
        ..Default::default()
    };
    let r = run_async_workload(&ctx.topo, &q, &rc);
    assert!(!r.crashed, "no crash armed in fig9");
    let t = ctx.topo.stats_total();
    let per = |x: u64| x as f64 / r.ops_done.max(1) as f64;
    (
        r.sim_mops,
        vec![
            ("pwbs/op".to_string(), per(t.pwbs)),
            ("psyncs/op".to_string(), per(t.psyncs)),
        ],
    )
}

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "fig9_async",
        "Fig 9: sync-blocking vs async-overlap (throughput x batch size)",
    );
    let ops = bench_ops();
    let batches = [1usize, 2, 4, 8, 16, 32];

    // Per-op durability: what a caller that must ack-after-persist pays
    // without the async layer. Independent of B — measure ONCE, then
    // replicate the measurement at every x so the flat series plots
    // alongside the sweeps (same de-duplication as fig8's baseline).
    suite.measure_extra("sync-blocking", batches[0] as f64, || {
        let cfg = QueueConfig { shards: SHARDS, batch: 1, batch_deq: 1, ..Default::default() };
        common::tput_point_extra("sharded-perlcrq", THREADS, ops, cfg, 42)
    });
    let baseline = suite.measurements.last().expect("just measured").clone();
    for &b in &batches[1..] {
        let mut m = baseline.clone();
        m.x = b as f64;
        suite.measurements.push(m);
    }

    for &b in &batches {
        // Group commit with buffered (return-races-durability) semantics.
        suite.measure_extra("sync-batched", b as f64, || {
            let cfg = QueueConfig {
                shards: SHARDS,
                batch: b,
                batch_deq: b,
                ..Default::default()
            };
            common::tput_point_extra("sharded-perlcrq", THREADS, ops, cfg, 42)
        });
        // Durability-gated futures over the same group commit.
        suite.measure_extra("async", b as f64, || async_point(b, ops));
    }

    // --- Claim checks (registered into BENCH_fig9_async.json) ---------
    suite.config("threads", THREADS);
    suite.config("shards", SHARDS);
    suite.config("ops", ops);
    let psyncs_at = |suite: &Suite, series: &str, x: f64| -> f64 {
        suite
            .measurements
            .iter()
            .filter(|m| m.series == series && (m.x - x).abs() < 1e-9)
            .flat_map(|m| m.extra.iter())
            .filter(|(name, _)| name == "psyncs/op")
            .map(|&(_, v)| v)
            .fold(f64::NAN, f64::max)
    };
    for &b in &batches {
        if b < 8 {
            continue;
        }
        let x = b as f64;
        let blocking = suite.mean_at("sync-blocking", x).unwrap();
        let asy = suite.mean_at("async", x).unwrap();
        let speedup = asy / blocking;
        suite.claim(
            &format!("fig9-overlap-b{b}"),
            "async completion beats sync-blocking >= 1.2x at B >= 8",
            speedup >= 1.2,
            format!("async/sync-blocking = {speedup:.2}x @ B={b}"),
        );
        // Async must not pay more persistence than the sync batched path
        // it rides (1/B enq + 1/K deq); small slack for the attach/
        // detach + final-drain psyncs.
        let ps_async = psyncs_at(&suite, "async", x);
        let ps_batched = psyncs_at(&suite, "sync-batched", x);
        suite.claim(
            &format!("fig9-psync-parity-b{b}"),
            "async pays no more psyncs/op than the sync batched path it rides",
            ps_async <= ps_batched * 1.10 + 0.01,
            format!("async {ps_async:.3} vs sync-batched {ps_batched:.3} @ B={b}"),
        );
    }
    suite.finish()?;
    anyhow::ensure!(suite.claims_pass(), "fig9 async claims failed");
    Ok(())
}
