//! Figure 7 (beyond the paper) — contention scaling of the sharded +
//! batched queue layer: simulated throughput over shards × threads ×
//! batch size for `sharded-perlcrq`, against the single PerLCRQ baseline.
//!
//! Expected shape: at high thread counts, throughput grows with the shard
//! count (the Head/Tail FAI serialization chains split K ways) and with
//! the batch size (psyncs amortize to 1/B per enqueue); at 1 thread the
//! variants converge (no contention to shed) and sharding overhead shows
//! up as a small constant cost.

#[path = "common/mod.rs"]
mod common;

use persiq::harness::bench::{bench_ops, Suite};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::queues::QueueConfig;

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "fig7_sharding",
        "Fig 7: sharded+batched scaling (shards x threads x batch)",
    );
    let ops = bench_ops();
    let threads: Vec<usize> = std::env::var("PERSIQ_THREADS")
        .ok()
        .map(|s| s.split(',').filter_map(|p| p.trim().parse().ok()).collect())
        .unwrap_or_else(|| vec![1, 2, 4, 8, 16, 32]);

    // Baseline: the unsharded PerLCRQ.
    for &n in &threads {
        suite.measure_extra("perlcrq", n as f64, || {
            common::tput_point_extra("perlcrq", n, ops, QueueConfig::default(), 42)
        });
    }

    // Shard sweep (per-op persistence).
    for shards in [1usize, 2, 4, 8] {
        let series = format!("sharded-s{shards}");
        for &n in &threads {
            let cfg = QueueConfig { shards, batch: 1, ..Default::default() };
            suite.measure_extra(&series, n as f64, || {
                common::tput_point_extra("sharded-perlcrq", n, ops, cfg.clone(), 42)
            });
        }
    }

    // Enqueue-batch sweep at 8 shards (group-commit amortization).
    for batch in [2usize, 4, 8] {
        let series = format!("sharded-s8-b{batch}");
        for &n in &threads {
            let cfg = QueueConfig { shards: 8, batch, ..Default::default() };
            suite.measure_extra(&series, n as f64, || {
                common::tput_point_extra("sharded-perlcrq", n, ops, cfg.clone(), 42)
            });
        }
    }

    // Both-endpoints batch sweep at 8 shards (consumer-side group commit
    // closes the dequeue asymmetry: psyncs amortize to ~1/K per op).
    for k in [2usize, 4, 8] {
        let series = format!("sharded-s8-b{k}-d{k}");
        for &n in &threads {
            let cfg = QueueConfig { shards: 8, batch: k, batch_deq: k, ..Default::default() };
            suite.measure_extra(&series, n as f64, || {
                common::tput_point_extra("sharded-perlcrq", n, ops, cfg.clone(), 42)
            });
        }
    }

    // Shape claims (the subsystem's headline claims), registered into
    // the BENCH_fig7_sharding.json artifact before finish() writes it.
    suite.config("threads", threads.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(","));
    suite.config("ops", ops);
    let hi = *threads.last().unwrap() as f64;
    let s1 = suite.mean_at("sharded-s1", hi).unwrap();
    let s8 = suite.mean_at("sharded-s8", hi).unwrap();
    let b8 = suite.mean_at("sharded-s8-b8", hi).unwrap();
    let bd8 = suite.mean_at("sharded-s8-b8-d8", hi).unwrap();
    suite.claim(
        "fig7-shard-scaling",
        "throughput grows with the shard count at high thread counts",
        s8 / s1 > 1.0,
        format!("8 shards / 1 shard = {:.2}x @ {hi} threads", s8 / s1),
    );
    suite.claim(
        "fig7-batch-amortization",
        "enqueue group commit beats per-op persistence at 8 shards",
        b8 / s8 > 1.0,
        format!("batch 8 / batch 1 = {:.2}x @ {hi} threads", b8 / s8),
    );
    suite.claim(
        "fig7-deq-batching",
        "adding consumer-side batching never loses to enqueue-only batching",
        bd8 / b8 >= 1.0,
        format!("+deq batch 8 / batch 8 = {:.2}x @ {hi} threads", bd8 / b8),
    );
    // Persistence-cost claim: with both endpoints batched at K, the pairs
    // workload must land under 2/K psyncs per operation.
    for k in [2usize, 4, 8] {
        let series = format!("sharded-s8-b{k}-d{k}");
        let psyncs = suite
            .measurements
            .iter()
            .filter(|m| m.series == series)
            .flat_map(|m| m.extra.iter())
            .filter(|(name, _)| name == "psyncs/op")
            .map(|&(_, v)| v)
            .fold(f64::NAN, f64::max);
        let bound = 2.0 / k as f64;
        suite.claim(
            &format!("fig7-psyncs-k{k}"),
            "both endpoints batched at K keep the pairs workload under 2/K psyncs/op",
            psyncs < bound,
            format!("max psyncs/op {psyncs:.3} vs bound {bound:.3} @ K={k}"),
        );
    }
    // Verdicts are recorded (stdout + artifact), not process-fatal: fig7
    // ran as a report-only figure before the artifact existed, and quick
    // low-op CI runs may flatten the scaling shape.
    suite.finish()?;
    Ok(())
}
