//! M1 — pmem primitive cost microbenchmark: the substrate's simulated
//! costs for each primitive on private vs global lines (calibration table
//! quoted in EXPERIMENTS.md).

use std::sync::Arc;

use persiq::harness::bench::Suite;
use persiq::pmem::{Hotness, PmemConfig, PmemPool};

fn main() -> anyhow::Result<()> {
    let mut suite = Suite::new("micro_pmem", "M1: pmem primitive simulated costs (ns/op)");
    let pool = Arc::new(PmemPool::new(PmemConfig::default().with_capacity(1 << 16)));
    pool.set_active_threads(16);
    let cold = pool.alloc_lines(1);
    let hot = pool.alloc_lines(1);
    pool.set_hot(cold, 8, Hotness::Private);
    pool.set_hot(hot, 8, Hotness::Global);
    let iters = 50_000u64;
    let mut point = |name: &str, f: &dyn Fn()| {
        pool.reset_meter();
        let t0 = pool.vtime(0);
        for _ in 0..iters {
            f();
        }
        let per = (pool.vtime(0) - t0) as f64 / iters as f64;
        suite.measure(name, 1.0, || per);
    };
    point("load_private", &|| {
        let _ = pool.load(0, cold);
    });
    point("load_global", &|| {
        let _ = pool.load(0, hot);
    });
    point("fai_private", &|| {
        let _ = pool.fai(0, cold);
    });
    point("fai_global", &|| {
        let _ = pool.fai(0, hot);
    });
    point("cas2_private", &|| {
        let _ = pool.cas2(0, cold, (0, 0), (0, 0));
    });
    point("pwb+psync_private", &|| {
        pool.pwb(0, cold);
        pool.psync(0);
    });
    point("pwb+psync_global", &|| {
        pool.pwb(0, hot);
        pool.psync(0);
    });
    suite.finish()
}
