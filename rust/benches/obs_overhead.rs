//! Observability overhead — the `persiq::obs` acceptance gate: with the
//! metrics registry enabled *and* the persistent flight recorder armed
//! (counters on, JSONL tracing off) the fig7 steady-state configuration
//! must stay within 5% of the throughput it reaches with both disabled.
//!
//! Samples are interleaved (off, on, off, on, ...) after a warmup round
//! so drift in the host affects both series equally, and the gate
//! compares medians. `PERSIQ_OBS_MAX_OVERHEAD` overrides the 5% bound;
//! `PERSIQ_BENCH_REPEATS` the sample count per series.

#[path = "common/mod.rs"]
mod common;

use persiq::harness::bench::{bench_ops, Suite};
use persiq::harness::runner::{run_workload, RunConfig};
use persiq::harness::Workload;
use persiq::obs;
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::queues::{by_name, QueueConfig};

/// Fig7 steady-state point (sharded-perlcrq, S = B = K = 8), wall-clock
/// Mops/s. `common::tput_point` reports simulated throughput, which is
/// blind to registry cost by construction — overhead only shows on the
/// wall clock.
fn wall_point(nthreads: usize, ops: u64, seed: u64) -> f64 {
    let qcfg = QueueConfig { shards: 8, batch: 8, batch_deq: 8, ..Default::default() };
    let c = common::ctx_with(nthreads, qcfg);
    let q = by_name("sharded-perlcrq").unwrap()(&c);
    let r = run_workload(
        &c.topo,
        &q,
        &RunConfig { nthreads, total_ops: ops, workload: Workload::Pairs, seed, ..Default::default() },
    );
    r.wall_mops
}

fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "obs_overhead",
        "obs registry overhead: fig7 steady state, enabled vs disabled",
    );
    let ops = bench_ops();
    let nthreads: usize = std::env::var("PERSIQ_THREADS")
        .ok()
        .and_then(|s| s.split(',').next().and_then(|p| p.trim().parse().ok()))
        .unwrap_or(4);
    let rounds = suite.repeats.max(3);

    // Warmup (both modes touch their code paths once, uncounted). The
    // "off" arm disarms the persistent flight recorder along with the
    // registry so the gate honestly prices the recorder's pwb/poke
    // traffic into the 5% bound, not just counter increments.
    obs::set_enabled(false);
    obs::flight::set_enabled(false);
    wall_point(nthreads, ops, 7);
    obs::set_enabled(true);
    obs::flight::set_enabled(true);
    wall_point(nthreads, ops, 7);

    // The enabled series also consumes the registry as a reporter would:
    // a windowed snapshot delta across its rounds.
    let snap0 = obs::registry().snapshot();

    let (mut off, mut on) = (Vec::new(), Vec::new());
    for round in 0..rounds {
        let seed = 100 + round as u64;
        obs::set_enabled(false);
        obs::flight::set_enabled(false);
        off.push(wall_point(nthreads, ops, seed));
        obs::set_enabled(true);
        obs::flight::set_enabled(true);
        on.push(wall_point(nthreads, ops, seed));
    }

    let delta = obs::registry().snapshot().delta(&snap0);
    let samples: usize = delta.families.iter().map(|f| f.samples.len() + f.hists.len()).sum();
    println!(
        "[registry window: {} families, {} samples across the enabled rounds]",
        delta.families.len(),
        samples
    );

    suite.repeats = rounds;
    let mut it = off.iter();
    suite.measure("obs-off", nthreads as f64, || *it.next().unwrap());
    let mut it = on.iter();
    suite.measure("obs-on", nthreads as f64, || *it.next().unwrap());

    let (m_off, m_on) = (median(&off), median(&on));
    let overhead = 1.0 - m_on / m_off;
    let max_overhead: f64 = std::env::var("PERSIQ_OBS_MAX_OVERHEAD")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.05);
    suite.config("threads", nthreads);
    suite.config("ops", ops);
    suite.config("rounds", rounds);
    suite.claim(
        "obs-overhead-gate",
        "registry + flight recorder cost under the overhead bound on fig7 steady state",
        overhead <= max_overhead,
        format!(
            "median wall Mops off={m_off:.3} on={m_on:.3} -> overhead {:.2}% (bound {:.0}%)",
            overhead * 100.0,
            max_overhead * 100.0
        ),
    );
    suite.finish()?;
    anyhow::ensure!(
        overhead <= max_overhead,
        "obs registry overhead {:.2}% exceeds the {:.0}% bound",
        overhead * 100.0,
        max_overhead * 100.0
    );
    Ok(())
}
