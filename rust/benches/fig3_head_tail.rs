//! Figure 3 — "Cost of persisting Head and Tail in PerLCRQ": PerLCRQ vs
//! PerLCRQ(no head) vs PerLCRQ(no tail), plus PerLCRQ-PHead for reference.
//!
//! Expected shape (paper): persisting Tail is nearly free (closedFlag
//! works, closes are rare); the local-copy Head persist costs a little
//! (PerLCRQ vs no-head gap); the shared-Head persist costs a lot.

#[path = "common/mod.rs"]
mod common;

use persiq::harness::bench::{bench_ops, thread_sweep, Suite};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::queues::QueueConfig;

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "fig3_head_tail",
        "Fig 3: cost of persisting Head/Tail (PerLCRQ vs no-head vs no-tail vs PHead)",
    );
    let ops = bench_ops();
    for algo in ["perlcrq", "perlcrq-nohead", "perlcrq-notail", "perlcrq-phead"] {
        for &n in &thread_sweep() {
            suite.measure_extra(algo, n as f64, || {
                common::tput_point_extra(algo, n, ops, QueueConfig::default(), 43)
            });
        }
    }
    suite.finish()?;

    let hi = *thread_sweep().last().unwrap() as f64;
    let base = suite.mean_at("perlcrq", hi).unwrap();
    let nohead = suite.mean_at("perlcrq-nohead", hi).unwrap();
    let notail = suite.mean_at("perlcrq-notail", hi).unwrap();
    println!("\nclaims @ {hi} threads:");
    println!("  no-tail/base = {:.3} (paper: ~1.0 — Tail persist negligible)", notail / base);
    println!("  no-head/base = {:.3} (paper: > 1 — local Head persist has a cost)", nohead / base);
    Ok(())
}
