//! Sensitivity analysis — is the Fig. 2 ordering an artifact of the cost
//! calibration? Sweep the three most influential knobs (transfer cost,
//! psync latency, NVM media cost) across a 4x range each and check the
//! paper's two qualitative claims at 48 simulated threads:
//!   (1) PerLCRQ >= 2x PBQueue;
//!   (2) PerLCRQ-PHead below PBQueue.

#[path = "common/mod.rs"]
mod common;

use persiq::harness::bench::{bench_ops, Suite};
use persiq::harness::runner::{run_workload, RunConfig};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::{CostModel, PmemConfig};
use persiq::queues::{by_name, QueueConfig, QueueCtx};

fn point(algo: &str, cost: &CostModel, ops: u64) -> f64 {
    let ctx = QueueCtx::single(
        PmemConfig::default().with_capacity(1 << 22).with_cost(cost.clone()),
        48,
        QueueConfig::default(),
    );
    let q = by_name(algo).unwrap()(&ctx);
    run_workload(
        &ctx.topo,
        &q,
        &RunConfig { nthreads: 48, total_ops: ops, seed: 52, ..Default::default() },
    )
    .sim_mops
}

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "sensitivity",
        "cost-model sensitivity: PerLCRQ/PBQueue ratio @48 threads under knob sweeps",
    );
    let ops = bench_ops();
    let mut all_hold = true;
    for (knob, values) in [
        ("conflict_ns", vec![60u64, 120, 240]),
        ("psync_ns", vec![125u64, 250, 500]),
        ("nvm_flush_ns", vec![35u64, 70, 140]),
    ] {
        for &v in &values {
            let mut cost = CostModel::default();
            match knob {
                "conflict_ns" => cost.conflict_ns = v,
                "psync_ns" => cost.psync_ns = v,
                "nvm_flush_ns" => cost.nvm_flush_ns = v,
                _ => unreachable!(),
            }
            let perlcrq = point("perlcrq", &cost, ops);
            let pbq = point("pbqueue", &cost, ops);
            let phead = point("perlcrq-phead", &cost, ops);
            let ratio = perlcrq / pbq;
            let claim1 = ratio >= 2.0;
            let claim2 = phead < pbq * 1.15; // allow slack at the crossover
            all_hold &= claim1 && claim2;
            suite.measure_extra(&format!("{knob}={v}"), v as f64, || {
                (
                    ratio,
                    vec![
                        ("perlcrq".to_string(), perlcrq),
                        ("pbqueue".to_string(), pbq),
                        ("phead".to_string(), phead),
                        ("claims_hold".to_string(), f64::from(claim1 && claim2)),
                    ],
                )
            });
        }
    }
    suite.finish()?;
    println!(
        "\nqualitative claims (PerLCRQ >= 2x PBQueue; PHead <= ~PBQueue) hold across \
         all knob settings: {all_hold}"
    );
    println!(
        "(expected finding: doubling nvm_flush_ns narrows the ratio toward ~2x — \
         flush bandwidth is exactly what batch-flushing combining economizes; the \
         ordering itself never flips)"
    );
    Ok(())
}
