//! Ablation A4 — the Alg. 6 tradeoff surface: PerIQ endpoint-persist
//! interval k ∈ {1, 10, 100, 1000, ∞} → throughput AND recovery time,
//! the full persistence-cost/recovery-cost tradeoff the paper highlights
//! as contribution (2).

#[path = "common/mod.rs"]
mod common;

use persiq::harness::bench::{bench_ops, Suite};
use persiq::harness::failure::{mean_recovery_sim_ns, run_cycles, CycleConfig};
use persiq::harness::runner::RunConfig;
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::queues::{persistent_by_name, QueueConfig};

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "ablation_persist_interval",
        "A4: PerIQ persist interval k -> throughput + recovery time",
    );
    let ops = bench_ops();
    for &k in &[1usize, 10, 100, 1000, 0] {
        let x = if k == 0 { 1e6 } else { k as f64 }; // 0 = never ~ "infinity"
        let qcfg =
            QueueConfig { periq_tail_interval: k, iq_capacity: 1 << 20, ..Default::default() };
        suite.measure_extra("periq", x, || {
            let tput = common::tput_point("periq", 16, ops, qcfg.clone(), 50);
            // Recovery cost at this interval (3 cycles).
            let c = common::ctx_with(4, qcfg.clone());
            let q = persistent_by_name("periq").unwrap()(&c);
            let res = run_cycles(
                &c.topo,
                &q,
                &CycleConfig {
                    cycles: 3,
                    steps: 200_000,
                    run: RunConfig { nthreads: 4, total_ops: u64::MAX / 2, ..Default::default() },
                    seed: 51,
                },
            );
            (tput, vec![("recovery_us".to_string(), mean_recovery_sim_ns(&res) / 1e3)])
        });
    }
    suite.finish()?;
    println!("\n(the tradeoff: small k -> lower throughput, flat recovery; k=inf -> max throughput, recovery grows)");
    Ok(())
}
