//! Ablation A3 — the §4.2 closedFlag optimization: with a small ring
//! (frequent closes), disabling the flag forces every CLOSED observer to
//! re-persist Tail. Reports throughput and pwbs/op.

#[path = "common/mod.rs"]
mod common;

use persiq::harness::bench::{bench_ops, Suite};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::queues::QueueConfig;

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "ablation_closed_flag",
        "A3: closedFlag on/off under frequent ring closes (R = 64)",
    );
    let ops = bench_ops();
    for (series, disabled) in [("closedflag-on", false), ("closedflag-off", true)] {
        for &n in &[8usize, 32] {
            let qcfg = QueueConfig {
                ring_size: 64,
                starvation_limit: 64,
                disable_closed_flag: disabled,
                ..Default::default()
            };
            suite.measure_extra(series, n as f64, || {
                common::tput_point_extra("perlcrq", n, ops, qcfg.clone(), 49)
            });
        }
    }
    suite.finish()
}
