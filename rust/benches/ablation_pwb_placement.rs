//! Ablation A2 — the persistence principles of [1] quantified: PerLCRQ's
//! single low-contention pair vs durable-MSQ's eager persist-everything on
//! hot endpoints vs PBQueue's batch-amortized persists. Reports both
//! throughput and pwb/psync counts per operation.

#[path = "common/mod.rs"]
mod common;

use persiq::harness::bench::{bench_ops, Suite};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::queues::QueueConfig;

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "ablation_pwb_placement",
        "A2: persistence-instruction placement (counts + cost) at 16/48 threads",
    );
    let ops = bench_ops();
    for algo in ["perlcrq", "perlcrq-phead", "durable-msq", "pbqueue"] {
        for &n in &[16usize, 48] {
            suite.measure_extra(algo, n as f64, || {
                common::tput_point_extra(algo, n, ops, QueueConfig::default(), 48)
            });
        }
    }
    suite.finish()
}
