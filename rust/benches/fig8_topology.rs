//! Figure 8 (beyond the paper) — NVM topology placement: colocated vs
//! interleaved shard placement on a 2-socket topology as the cross-socket
//! `pwb` penalty grows.
//!
//! The paper's premise is that persistence latencies of different threads
//! overlap once the `pwb`/`psync` pairs land on low-contention locations.
//! On a multi-socket machine that overlap is bounded per socket: a `pwb`
//! crossing the interconnect pays `remote_pwb_ns` and lands on the
//! *remote* socket's NVM bandwidth chain. Colocated placement (threads
//! enqueue to their home socket's shards; batch logs on the home pool)
//! keeps every flush socket-local and its group-commit flush down to one
//! `psync`; interleaved placement pays the penalty on ~half its flushes
//! and its batches span both pools (one `psync` each).
//!
//! Expected shape: the colocate/interleave throughput ratio is ~1 at
//! `remote_pwb_ns = 0` and grows with the penalty; at
//! `remote_pwb_ns >= 2 x pwb_ns` colocated wins by >= 1.3x, while its
//! psyncs/op stay at the single-pool batched level (1/B per enqueue +
//! 1/K per dequeue).

use persiq::harness::bench::{bench_ops, Suite};
use persiq::harness::runner::{run_workload, RunConfig};
use persiq::harness::Workload;
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::{CostModel, PlacementPolicy, PmemConfig, Topology};
use persiq::queues::{by_name, QueueConfig, QueueCtx};

const THREADS: usize = 4;
const SHARDS: usize = 4;
const BATCH: usize = 4; // B (enqueue) and K (dequeue) group-commit sizes

/// One point: sim Mops/s + psyncs/op + remote ops/op.
fn point(pools: usize, placement: PlacementPolicy, remote_pwb_ns: u64) -> (f64, f64, f64) {
    // The RMW penalty rides the same interconnect hop; sweep it in
    // lockstep (published cross-socket atomic penalties sit in the same
    // 2-4x band as remote flushes).
    let cost = CostModel {
        remote_pwb_ns,
        remote_rmw_ns: remote_pwb_ns,
        ..CostModel::default()
    };
    let pmem = PmemConfig {
        capacity_words: 1 << 22,
        cost,
        evict_prob: 0.25,
        pending_flush_prob: 0.5,
        seed: 0xF18,
    };
    let qcfg = QueueConfig {
        shards: SHARDS,
        batch: BATCH,
        batch_deq: BATCH,
        ring_size: 1 << 10,
        placement,
        ..Default::default()
    };
    let ctx = QueueCtx { topo: Topology::new(pmem, pools), nthreads: THREADS, cfg: qcfg };
    let q = by_name("sharded-perlcrq").unwrap()(&ctx);
    let r = run_workload(
        &ctx.topo,
        &q,
        &RunConfig {
            nthreads: THREADS,
            total_ops: bench_ops(),
            workload: Workload::Pairs,
            seed: 53,
            ..Default::default()
        },
    );
    let t = ctx.topo.stats_total();
    let per = |x: u64| x as f64 / r.ops_done.max(1) as f64;
    (r.sim_mops, per(t.psyncs), per(t.remote_ops))
}

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "fig8_topology",
        "Fig 8: colocated vs interleaved shard placement vs cross-socket pwb penalty \
         (2 pools, 4 shards, B=K=4, 4 threads)",
    );
    let base_pwb = CostModel::default().pwb_ns;
    let penalties: Vec<u64> = vec![0, base_pwb, 2 * base_pwb, 4 * base_pwb];

    // Single-pool batched baseline: the psyncs/op reference the colocated
    // multi-pool runs must match (placement must not change the
    // group-commit discipline).
    let mut base = (0.0, 0.0, 0.0);
    suite.measure_extra("single-pool", 0.0, || {
        base = point(1, PlacementPolicy::Interleave, 0);
        let (mops, psyncs, remote) = base;
        (mops, vec![("psyncs/op".to_string(), psyncs), ("remote/op".to_string(), remote)])
    });
    let (_, base_psyncs, base_remote) = base;
    assert_eq!(base_remote, 0.0, "a single pool can never cross sockets");

    let mut claims = Vec::new();
    for &pen in &penalties {
        let mut colo = (0.0, 0.0, 0.0);
        suite.measure_extra("colocate", pen as f64, || {
            colo = point(2, PlacementPolicy::Colocate, pen);
            let (mops, psyncs, remote) = colo;
            (mops, vec![("psyncs/op".to_string(), psyncs), ("remote/op".to_string(), remote)])
        });
        let mut inter = (0.0, 0.0, 0.0);
        suite.measure_extra("interleave", pen as f64, || {
            inter = point(2, PlacementPolicy::Interleave, pen);
            let (mops, psyncs, remote) = inter;
            (mops, vec![("psyncs/op".to_string(), psyncs), ("remote/op".to_string(), remote)])
        });
        claims.push((pen, colo, inter));
    }

    // Headline claims, registered into BENCH_fig8_topology.json.
    suite.config("threads", THREADS);
    suite.config("shards", SHARDS);
    suite.config("batch", BATCH);
    suite.config("pwb_ns", base_pwb);
    for (pen, colo, inter) in &claims {
        let ratio = colo.0 / inter.0.max(1e-12);
        if *pen >= 2 * base_pwb {
            suite.claim(
                &format!("fig8-colocate-wins-{pen}ns"),
                "colocated placement wins >= 1.3x once remote pwbs cost 2x local",
                ratio >= 1.3,
                format!(
                    "colocate/interleave = {ratio:.2}x @ remote_pwb={pen}ns \
                     (colo psyncs/op {:.3} remote/op {:.3}; inter psyncs/op {:.3} \
                     remote/op {:.3})",
                    colo.1, colo.2, inter.1, inter.2
                ),
            );
        } else {
            println!(
                "  remote_pwb={pen:>3}ns: colocate/interleave = {ratio:.2}x (no bound below \
                 the 2x penalty)"
            );
        }
    }
    // Cost discipline: colocated placement must not change the batched
    // psync budget — same psyncs/op as the single-pool batched baseline
    // (1/B per enqueue + 1/K per dequeue), and zero cross-socket ops.
    // (A colocated consumer may occasionally *steal* from a sibling
    // socket when its local shards run dry — allow that trickle.)
    for (pen, colo, _) in &claims {
        let drift = (colo.1 - base_psyncs).abs();
        suite.claim(
            &format!("fig8-psync-budget-{pen}ns"),
            "colocation keeps the single-pool psync budget and stays socket-local",
            drift < 0.02 && colo.2 < 0.01,
            format!(
                "psyncs/op {:.3} vs single-pool {:.3} (drift {drift:.3}), remote/op {:.3} \
                 @ remote_pwb={pen}ns",
                colo.1, base_psyncs, colo.2
            ),
        );
    }
    suite.finish()?;
    anyhow::ensure!(suite.claims_pass(), "fig8 topology claims failed");
    Ok(())
}
