//! Ablation A1 — ring size R sweep for PerLCRQ: larger rings amortize
//! node creation; too-small rings close constantly.

#[path = "common/mod.rs"]
mod common;

use persiq::harness::bench::{bench_ops, Suite};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::queues::QueueConfig;

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new("ablation_ring_size", "A1: PerLCRQ throughput vs ring size R");
    let ops = bench_ops();
    for &r in &[64usize, 256, 1024, 4096] {
        let qcfg = QueueConfig { ring_size: r, ..Default::default() };
        suite.measure("perlcrq", r as f64, || {
            common::tput_point("perlcrq", 16, ops, qcfg.clone(), 47)
        });
    }
    suite.finish()
}
