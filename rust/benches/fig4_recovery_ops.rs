//! Figure 4 — "Recovery time of PerIQ as the number of operations
//! increases": crash after N operations; average recovery cost over
//! cycles, for pure PerIQ vs the persist-endpoints variant (Alg. 6).
//!
//! Expected shape (paper): pure PerIQ's recovery grows with N (the tail
//! scan walks the used prefix); the persist variant stays flat.

#[path = "common/mod.rs"]
mod common;

use persiq::harness::bench::Suite;
use persiq::harness::failure::{mean_recovery_sim_ns, run_cycles, CycleConfig};
use persiq::harness::runner::RunConfig;
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::queues::{persistent_by_name, QueueConfig};

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "fig4_recovery_ops",
        "Fig 4: PerIQ recovery time vs ops executed before the crash",
    );
    let cycles = std::env::var("PERSIQ_CYCLES").ok().and_then(|s| s.parse().ok()).unwrap_or(5);
    for (series, interval) in [("periq", 0usize), ("periq-ptail", 1usize)] {
        for &ops in &[5_000u64, 20_000, 50_000, 100_000] {
            suite.measure(series, ops as f64, || {
                let qcfg = QueueConfig {
                    periq_tail_interval: interval,
                    iq_capacity: 1 << 20,
                    ..Default::default()
                };
                let c = common::ctx_with(4, qcfg.clone());
                c.topo.set_active_threads(4);
                // (ctor reads periq_tail_interval from the ctx config)
                let q = persistent_by_name("periq").unwrap()(&c);
                // Crash *after* roughly `ops` operations: the step budget
                // is per-primitive; PerIQ does ~8 primitives/op.
                let ccfg = CycleConfig {
                    cycles,
                    steps: ops * 8,
                    run: RunConfig { nthreads: 4, total_ops: u64::MAX / 2, ..Default::default() },
                    seed: 44,
                };
                let res = run_cycles(&c.topo, &q, &ccfg);
                mean_recovery_sim_ns(&res) / 1e3 // µs simulated
            });
        }
    }
    suite.finish()?;
    let grow = suite.mean_at("periq", 100_000.0).unwrap()
        / suite.mean_at("periq", 5_000.0).unwrap().max(1e-9);
    let flat = suite.mean_at("periq-ptail", 100_000.0).unwrap()
        / suite.mean_at("periq-ptail", 5_000.0).unwrap().max(1e-9);
    println!("\nclaims: pure grows {grow:.1}x from 5k->100k ops; persist-tail grows {flat:.1}x (paper: pure >> variant)");
    Ok(())
}
