//! Figure 13 (beyond the paper) — what the size-classed persistent
//! allocator (`pmem::palloc`) costs and buys versus the raw bump arena.
//!
//! Two experiments:
//!
//! * **Hot-path wall clock** — alloc/free pairs, palloc recycling
//!   (magazine hit: no shared word) vs the bump ablation (`recycle off`:
//!   every allocation takes the shared bump-cursor CAS + extent-directory
//!   append). Claims (env-overridable for small shared CI runners):
//!   uncontended (1 thread) the recycling path stays within 5% of bump
//!   (`PERSIQ_FIG13_MIN_UNCONTENDED`, default 0.95×); contended
//!   (`PERSIQ_FIG13_THREADS`, default 16) it wins by at least
//!   `PERSIQ_FIG13_MIN_SPEEDUP` (default 1.3×), because magazines remove
//!   the cursor from the steady-state path entirely.
//!
//! * **Persistence budget** — a node-churning sharded-perlcrq workload
//!   (8-slot ring: every few ops allocates and retires a ring node)
//!   run recycle-on and recycle-off must produce an **identical
//!   psync ledger, site by site**, with exactly zero psyncs at the
//!   `Alloc` site: allocator durability piggybacks on the psyncs the
//!   queue already issues, so the paper's `1/B + 1/K` budget is
//!   untouched.

use std::sync::{Arc, Barrier};
use std::time::Instant;

use persiq::harness::bench::{bench_ops, Suite};
use persiq::obs::{ObsSite, ALL_SITES};
use persiq::pmem::{CostModel, PmemConfig, PmemPool, Topology};
use persiq::queues::sharded::ShardedQueue;
use persiq::queues::{ConcurrentQueue, QueueConfig};

/// Segment size for the microbench (lines): small enough that the
/// recycled path's scrub-on-reuse stays comparable to a fresh carve.
const LINES: usize = 2;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Wall-clock Mpairs/s of `nthreads` threads doing alloc/free pairs.
/// A fresh pool per call: the bump ablation leaks by design, so the
/// arena must be sized for the whole run (and the capacity caps the
/// pair budget below).
fn pair_rate(nthreads: usize, pairs_per_thread: u64, recycle: bool, seed: u64) -> f64 {
    let pool = Arc::new(PmemPool::new(PmemConfig {
        capacity_words: 1 << 23,
        cost: CostModel::zero(),
        evict_prob: 0.0,
        pending_flush_prob: 0.0,
        seed,
    }));
    pool.palloc().set_recycle(recycle);
    let barrier = Arc::new(Barrier::new(nthreads + 1));
    let mut hs = Vec::new();
    for tid in 0..nthreads {
        let pool = Arc::clone(&pool);
        let barrier = Arc::clone(&barrier);
        hs.push(std::thread::spawn(move || {
            barrier.wait();
            for i in 0..pairs_per_thread {
                let a = pool.palloc_alloc(tid, LINES).expect("arena exhausted mid-bench");
                pool.palloc_free(tid, a);
                // Callers psync anyway (group commits); keep the pending
                // flush queues bounded the same way in both modes.
                if i % 64 == 63 {
                    pool.psync(tid);
                }
            }
            pool.psync(tid);
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    for h in hs {
        h.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64().max(1e-9);
    (nthreads as u64 * pairs_per_thread) as f64 / dt / 1e6
}

/// One deterministic node-churning queue run; returns the per-site psync
/// ledger and the recycled-segment count.
fn ledger_run(recycle: bool) -> (persiq::obs::SiteLedger, u64) {
    let topo = Topology::single(PmemConfig {
        capacity_words: 1 << 22,
        cost: CostModel::zero(),
        evict_prob: 0.0,
        pending_flush_prob: 1.0,
        seed: 9,
    });
    let q = ShardedQueue::new_perlcrq(
        &topo,
        1,
        QueueConfig {
            shards: 4,
            batch: 8,
            batch_deq: 8,
            ring_size: 8,
            recycle,
            ..Default::default()
        },
    )
    .unwrap();
    let n = 4096u64;
    for v in 0..n {
        q.enqueue(0, v).unwrap();
    }
    for _ in 0..n {
        assert!(q.dequeue(0).unwrap().is_some());
    }
    q.flush(0);
    (topo.site_ledger(), topo.primary().palloc().recycled_total())
}

fn main() -> anyhow::Result<()> {
    let mut suite = Suite::new(
        "fig13_alloc",
        "Fig 13: size-classed persistent allocator — magazine hot path vs bump, zero extra psyncs",
    );
    let threads = env_usize("PERSIQ_FIG13_THREADS", 16);
    let ops = bench_ops().max(16_000);
    // Pair budgets, capped so the leaking bump ablation fits the arena:
    // (1 + LINES) lines per leaked pair, 2^23 words of arena.
    let uncontended_pairs = ops.clamp(16_000, 200_000);
    let pair_cap = (256_000 / threads.max(1) as u64).max(1_000);
    let contended_pairs = ((ops * 4) / threads.max(1) as u64).max(1_000).min(pair_cap);

    let mut best = [[0.0f64; 2]; 2]; // [uncontended|contended][bump|palloc]
    for (xi, (nthreads, pairs)) in
        [(1usize, uncontended_pairs), (threads, contended_pairs)].into_iter().enumerate()
    {
        for (si, (series, recycle)) in [("bump", false), ("palloc", true)].into_iter().enumerate() {
            suite.measure_extra(series, nthreads as f64, || {
                let rate = pair_rate(nthreads, pairs, recycle, 7 + xi as u64);
                best[xi][si] = best[xi][si].max(rate);
                (rate, vec![("pairs/thread".to_string(), pairs as f64)])
            });
        }
    }
    suite.config("threads", threads);
    suite.config("seg_lines", LINES);
    suite.config("ops", ops);

    // --- Claim 1: uncontended hot path within 5% of bump -------------
    let min_unc = env_f64("PERSIQ_FIG13_MIN_UNCONTENDED", 0.95);
    let ratio_unc = best[0][1] / best[0][0];
    suite.claim(
        "fig13-hot-path-uncontended",
        "single-thread alloc/free pairs: the magazine path stays within 5% of raw bump",
        ratio_unc >= min_unc,
        format!(
            "palloc {:.2} vs bump {:.2} Mpairs/s = {ratio_unc:.2}x (bound {min_unc:.2})",
            best[0][1], best[0][0]
        ),
    );

    // --- Claim 2: contended speedup ----------------------------------
    let min_speedup = env_f64("PERSIQ_FIG13_MIN_SPEEDUP", 1.3);
    let ratio_con = best[1][1] / best[1][0];
    suite.claim(
        "fig13-hot-path-contended",
        "with no shared word on the steady-state path, recycling beats the contended bump cursor",
        ratio_con >= min_speedup,
        format!(
            "palloc {:.2} vs bump {:.2} Mpairs/s @ {threads} threads = {ratio_con:.2}x \
             (bound {min_speedup:.2})",
            best[1][1], best[1][0]
        ),
    );

    // --- Claim 3+4: psync ledger unchanged, Alloc site psync-free ----
    let (on, on_recycled) = ledger_run(true);
    let (off, _) = ledger_run(false);
    let identical = ALL_SITES.iter().all(|&s| on.psyncs_at(s) == off.psyncs_at(s));
    let diff: Vec<String> = ALL_SITES
        .iter()
        .filter(|&&s| on.psyncs_at(s) != off.psyncs_at(s))
        .map(|&s| format!("{s}: {} vs {}", on.psyncs_at(s), off.psyncs_at(s)))
        .collect();
    suite.claim(
        "fig13-psync-budget-unchanged",
        "recycle on/off produce identical per-site psync ledgers on a node-churning workload",
        identical && on_recycled > 0,
        if identical {
            format!("all {} sites identical; {on_recycled} segments recycled", ALL_SITES.len())
        } else {
            format!("site mismatch: {}", diff.join(", "))
        },
    );
    suite.claim(
        "fig13-alloc-site-psync-free",
        "the Alloc site carries zero psyncs: allocator durability piggybacks on caller psyncs",
        on.psyncs_at(ObsSite::Alloc) == 0 && off.psyncs_at(ObsSite::Alloc) == 0,
        format!(
            "Alloc psyncs: on={} off={} (pwbs on={} off={})",
            on.psyncs_at(ObsSite::Alloc),
            off.psyncs_at(ObsSite::Alloc),
            on.pwbs_at(ObsSite::Alloc),
            off.pwbs_at(ObsSite::Alloc)
        ),
    );

    suite.finish()?;
    anyhow::ensure!(suite.claims_pass(), "fig13 alloc claims failed");
    Ok(())
}
