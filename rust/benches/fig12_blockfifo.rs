//! Figure 12 (beyond the paper) — what block-granular claiming buys over
//! the sharded/batched per-op tier, and what it costs in ordering.
//!
//! Three series at high simulated parallelism, pairs workload:
//!
//! * **sharded-perlcrq** — the repo's production tier (8 shards, B = K =
//!   8 group commit): FAI per operation, psync per sealed batch.
//! * **blockfifo** — the block-granular tier (8 lanes, 32-entry blocks):
//!   one FAI *and* one psync per block on each side, i.e. `1/32` of both
//!   per operation.
//! * **blockfifo-multi** — same, with d-choice consumer sampling.
//!
//! Headline claims (checked below; thresholds env-overridable for small
//! shared CI runners):
//!
//! * **throughput** — blockfifo (and -multi) simulated Mops/s ≥
//!   `PERSIQ_FIG12_MIN_SPEEDUP` (default 2.0) × sharded-perlcrq at
//!   `THREADS` (default 32) simulated threads;
//! * **persistence budget** — blockfifo psyncs/op ≤ `1/block` +
//!   `PERSIQ_FIG12_PSYNC_EPS` (default 0.01);
//! * **bounded relaxation** — a recorded run probed with the
//!   `--relax auto` machinery (unbounded pass collecting per-dequeue
//!   overtake counts) reports p50/p99/max, and the calibrated bound
//!   stays at or below the static `block_relaxation` formula the
//!   checker would apply — i.e. the tier really is *boundedly* relaxed,
//!   and the recorded history verifies clean under the standard policy.

use std::sync::Arc;

use persiq::config::Config;
use persiq::harness::bench::{bench_ops, Suite};
use persiq::harness::runner::{drain_all, run_workload};
use persiq::harness::{RunConfig, Workload};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::{Topology, WORDS_PER_LINE};
use persiq::queues::{persistent_by_name, ConcurrentQueue, QueueConfig, QueueCtx};
use persiq::verify::{
    block_relaxation, calibrate_relaxation, check_with, options_for, overtake_stats,
    CheckOptions, History,
};

const SHARDS: usize = 8;
const BATCH: usize = 8;
const BLOCK: usize = 32;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Queue config for one series, with blockfifo's lanes sized to the run:
/// block recycling (on by default since fig13) would cover any backlog,
/// but the figure compares steady-state scan costs, so the lanes are
/// still sized for `shards * ring_size * block` to cover every enqueue
/// the workload can issue (with 2x headroom) — recycled claims then stay
/// a rarity and the measured path matches the fig12 model.
fn qcfg_for(algo: &str, enqueues: u64) -> QueueConfig {
    let mut qcfg = QueueConfig {
        shards: SHARDS,
        batch: BATCH,
        batch_deq: BATCH,
        block: BLOCK,
        ..Default::default()
    };
    if algo.starts_with("blockfifo") {
        qcfg.ring_size =
            ((enqueues as usize / BLOCK / SHARDS + 1) * 2).next_power_of_two().max(64);
    }
    qcfg
}

/// Context sized for the series: blockfifo's block arrays can outgrow the
/// default arena at large `PERSIQ_OPS`, so scale the pool to the lanes.
fn ctx_for(nthreads: usize, qcfg: QueueConfig) -> QueueCtx {
    let mut cfg = Config::load_default();
    let stride = (qcfg.block + 1).div_ceil(WORDS_PER_LINE) * WORDS_PER_LINE;
    let need = (qcfg.shards * qcfg.ring_size * stride * 2).next_power_of_two();
    cfg.pmem.capacity_words = cfg.pmem.capacity_words.max(need);
    cfg.queue = qcfg;
    QueueCtx { topo: Topology::new(cfg.pmem.clone(), 1), nthreads, cfg: cfg.queue }
}

/// One throughput point: simulated Mops/s plus persistence counts per op.
fn point(algo: &str, nthreads: usize, ops: u64, seed: u64) -> (f64, f64, f64) {
    let qcfg = qcfg_for(algo, ops / 2 + ops / 8);
    let c = ctx_for(nthreads, qcfg);
    let q = persistent_by_name(algo).unwrap_or_else(|| panic!("unknown algo {algo}"))(&c);
    let qc: Arc<dyn ConcurrentQueue> = Arc::clone(&q) as _;
    let r = run_workload(
        &c.topo,
        &qc,
        &RunConfig { nthreads, total_ops: ops, workload: Workload::Pairs, seed, ..Default::default() },
    );
    let t = c.topo.stats_total();
    let per = |x: u64| x as f64 / r.ops_done.max(1) as f64;
    (r.sim_mops, per(t.psyncs), per(t.pwbs))
}

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "fig12_blockfifo",
        "Fig 12: block-granular claiming — FAI + psync amortized over whole blocks",
    );
    let threads = env_usize("PERSIQ_FIG12_THREADS", 32);
    let ops = bench_ops().max(16_000);

    let mut tput = [0.0f64; 3]; // [sharded, blockfifo, blockfifo-multi]
    let mut psyncs = [0.0f64; 3];
    for (i, algo) in ["sharded-perlcrq", "blockfifo", "blockfifo-multi"].iter().enumerate() {
        suite.measure_extra(algo, threads as f64, || {
            let (mops, ps, pw) = point(algo, threads, ops, 7 + i as u64);
            tput[i] = tput[i].max(mops);
            psyncs[i] = ps;
            (mops, vec![("psyncs/op".to_string(), ps), ("pwbs/op".to_string(), pw)])
        });
    }
    suite.config("threads", threads);
    suite.config("shards", SHARDS);
    suite.config("batch", BATCH);
    suite.config("block", BLOCK);
    suite.config("ops", ops);

    // --- Claim 1: throughput at high parallelism ---------------------
    let min_speedup = env_f64("PERSIQ_FIG12_MIN_SPEEDUP", 2.0);
    for (i, algo) in ["blockfifo", "blockfifo-multi"].iter().enumerate() {
        let speedup = tput[i + 1] / tput[0];
        suite.claim(
            &format!("fig12-speedup-{algo}"),
            "block-granular claiming beats the sharded tier at high parallelism",
            speedup >= min_speedup,
            format!("{algo}/sharded-perlcrq = {speedup:.2}x @ {threads} threads (bound {min_speedup:.2})"),
        );
    }

    // --- Claim 2: persistence budget ---------------------------------
    let eps = env_f64("PERSIQ_FIG12_PSYNC_EPS", 0.01);
    let budget = 1.0 / BLOCK as f64 + eps;
    for (i, algo) in ["blockfifo", "blockfifo-multi"].iter().enumerate() {
        suite.claim(
            &format!("fig12-psync-budget-{algo}"),
            "one psync per sealed block: psyncs/op stays within 1/block + eps",
            psyncs[i + 1] <= budget,
            format!("{algo} psyncs/op {:.4} vs budget {budget:.4}", psyncs[i + 1]),
        );
    }

    // --- Claim 3: bounded relaxation, measured -----------------------
    // A smaller recorded run through the --relax auto machinery: probe
    // with an unbounded pass collecting overtake counts, report the
    // distribution, and require the calibrated bound to stay within the
    // static formula the checker applies by default.
    let probe_threads = 8usize;
    let probe_ops = (ops / 4).max(8_000);
    let qcfg = qcfg_for("blockfifo", probe_ops);
    let c = ctx_for(probe_threads, qcfg);
    let q = persistent_by_name("blockfifo").unwrap()(&c);
    let qc: Arc<dyn ConcurrentQueue> = Arc::clone(&q) as _;
    let r = run_workload(
        &c.topo,
        &qc,
        &RunConfig {
            nthreads: probe_threads,
            total_ops: probe_ops,
            workload: Workload::Pairs,
            record: true,
            salt: 1,
            seed: 13,
            ..Default::default()
        },
    );
    q.quiesce();
    let drained = drain_all(&qc, 0);
    let h = History::from_logs(r.logs, drained);
    let opts = options_for("blockfifo", probe_threads, &c.cfg, 0);
    let probe = check_with(
        &h,
        &CheckOptions { relaxation: usize::MAX, collect_overtakes: true, max_report: 0, ..opts },
    );
    let stats = overtake_stats(&probe.overtake_counts);
    let auto = calibrate_relaxation(&probe.overtake_counts);
    let static_bound = block_relaxation(probe_threads, SHARDS, BLOCK);
    println!(
        "fig12: observed overtakes p50={} p99={} max={} over {} dequeues \
         (calibrated k={auto}, static bound {static_bound})",
        stats.p50, stats.p99, stats.max, stats.checked
    );
    suite.claim(
        "fig12-bounded-relaxation",
        "the calibrated FIFO relaxation stays within the static block formula",
        auto <= static_bound,
        format!("calibrated k={auto} vs static bound {static_bound}"),
    );
    let rep = check_with(&h, &opts);
    suite.claim(
        "fig12-history-verifies",
        "the recorded history verifies under the standard blockfifo policy",
        rep.ok(),
        format!("k={}, violations={}", opts.relaxation, rep.violations.len()),
    );

    suite.finish()?;
    anyhow::ensure!(suite.claims_pass(), "fig12 blockfifo claims failed");
    Ok(())
}
