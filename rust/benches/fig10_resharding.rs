//! Figure 10 (beyond the paper) — elastic re-sharding under load: what an
//! **online** grow/shrink of the stripe set costs, measured in throughput
//! windows around the transition.
//!
//! One sharded queue runs a pairs workload split into `WINDOWS` equal
//! measurement windows; in the middle window thread 0 triggers
//! `resize(to_k)` while every other thread keeps operating. Per window we
//! record simulated Mops/s and psyncs/op.
//!
//! Headline claims (checked below), for both grow (4→8) and shrink
//! (8→4):
//!
//! * **recovery** — throughput in the first post-transition window is
//!   ≥ 0.9× the pre-transition steady state (the transition is a blip,
//!   not a regime change);
//! * **cost isolation** — psyncs/op outside the transition window is
//!   unchanged (≤ steady × 1.10 + 0.02): the resize's `new_k + 3` psyncs
//!   are confined to the window they happen in.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use persiq::harness::bench::{bench_ops, Suite};
use persiq::harness::runner::run_workload;
use persiq::harness::{MidHook, RunConfig, Workload};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::queues::sharded::ShardedQueue;
use persiq::queues::{ConcurrentQueue, QueueConfig};

const THREADS: usize = 4;
const BATCH: usize = 4;
const WINDOWS: usize = 6;
/// The transition fires halfway through this window.
const RESIZE_WINDOW: usize = 2;

struct WindowPoint {
    sim_mops: f64,
    psyncs_per_op: f64,
}

/// Run one full windowed series: `from_k` stripes, resized online to
/// `to_k` in the middle window. Returns per-window points.
fn windowed_series(from_k: usize, to_k: usize, ops_per_window: u64) -> Vec<WindowPoint> {
    let qcfg = QueueConfig {
        shards: from_k,
        batch: BATCH,
        batch_deq: BATCH,
        ..Default::default()
    };
    let ctx = common::ctx_with(THREADS, qcfg.clone());
    let q = Arc::new(
        ShardedQueue::new_perlcrq(&ctx.topo, THREADS, qcfg).expect("valid bench config"),
    );
    let as_conc: Arc<dyn ConcurrentQueue> = Arc::clone(&q) as _;
    let mut out = Vec::with_capacity(WINDOWS);
    for w in 0..WINDOWS {
        let mid_hook = (w == RESIZE_WINDOW).then(|| {
            let hq = Arc::clone(&q);
            MidHook(Arc::new(move |tid: usize| {
                hq.resize(tid, to_k).expect("online resize must commit");
            }))
        });
        let rc = RunConfig {
            nthreads: THREADS,
            total_ops: ops_per_window,
            workload: Workload::Pairs,
            seed: 42 + w as u64,
            salt: w as u64 + 1,
            hook_after: if mid_hook.is_some() {
                (ops_per_window / THREADS as u64 / 2).max(1)
            } else {
                0
            },
            mid_hook,
            ..Default::default()
        };
        let r = run_workload(&ctx.topo, &as_conc, &rc);
        let stats = ctx.topo.stats_total();
        out.push(WindowPoint {
            sim_mops: r.sim_mops,
            psyncs_per_op: stats.psyncs as f64 / r.ops_done.max(1) as f64,
        });
    }
    assert_eq!(q.plan_epoch(), 2, "the mid-window resize must have committed");
    assert!(
        q.draining_info(0).is_none(),
        "the pairs workload's dequeue traffic must have retired the frozen plan"
    );
    out
}

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "fig10_resharding",
        "Fig 10: online re-sharding — throughput windows around a grow/shrink transition",
    );
    let ops_per_window = bench_ops().max(WINDOWS as u64 * 1_000) / WINDOWS as u64;

    suite.config("threads", THREADS);
    suite.config("batch", BATCH);
    suite.config("windows", WINDOWS);
    suite.config("ops_per_window", ops_per_window);
    let series = [("grow-4to8", 4usize, 8usize), ("shrink-8to4", 8, 4)];
    for (name, from_k, to_k) in series {
        let points = windowed_series(from_k, to_k, ops_per_window);
        for (w, p) in points.iter().enumerate() {
            // Windows are deterministic given the seed; record the
            // computed point (repeats would re-run past the transition
            // and measure a different regime).
            suite.measure_extra(name, w as f64, || {
                (p.sim_mops, vec![("psyncs/op".to_string(), p.psyncs_per_op)])
            });
        }
        // --- Claims (registered into BENCH_fig10_resharding.json) ----
        let steady_tput =
            (points[0].sim_mops + points[1].sim_mops) / 2.0;
        let steady_psync =
            (points[0].psyncs_per_op + points[1].psyncs_per_op) / 2.0;
        let post = &points[RESIZE_WINDOW + 1];
        let ratio = post.sim_mops / steady_tput;
        suite.claim(
            &format!("fig10-recovery-{name}"),
            "the first post-transition window recovers >= 0.9x steady throughput",
            ratio >= 0.9,
            format!("post-resize window tput = {ratio:.2}x steady"),
        );
        let worst = points
            .iter()
            .enumerate()
            .filter(|(w, _)| *w != RESIZE_WINDOW) // that window carries the resize psyncs
            .map(|(_, p)| p.psyncs_per_op)
            .fold(f64::NAN, f64::max);
        suite.claim(
            &format!("fig10-cost-isolation-{name}"),
            "psyncs/op outside the transition window stays at the steady budget",
            worst <= steady_psync * 1.10 + 0.02,
            format!("worst non-transition window {worst:.3} vs steady {steady_psync:.3}"),
        );
    }

    suite.finish()?;
    anyhow::ensure!(suite.claims_pass(), "fig10 re-sharding claims failed");
    Ok(())
}
