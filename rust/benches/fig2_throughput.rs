//! Figure 2 — "Performance comparison of PerLCRQ with PBQueue and
//! PWFQueue": simulated throughput vs thread count for PerLCRQ, its
//! best competitors, and PerLCRQ-PHead (the persist-shared-Head variant
//! whose collapse motivates §4.2 local persistence).
//!
//! Expected shape (paper): PerLCRQ ≥ 2× PBQueue everywhere; PerLCRQ-PHead
//! falls below PBQueue/PWFQueue as threads grow.

#[path = "common/mod.rs"]
mod common;

use persiq::harness::bench::{bench_ops, thread_sweep, Suite};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::queues::QueueConfig;
use persiq::runtime::MetricsEngine;

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "fig2_throughput",
        "Fig 2: throughput vs threads (PerLCRQ vs PBQueue vs PWFQueue vs PerLCRQ-PHead)",
    );
    let ops = bench_ops();
    for algo in ["perlcrq", "pbqueue", "pwfqueue", "perlcrq-phead"] {
        for &n in &thread_sweep() {
            suite.measure(algo, n as f64, || {
                common::tput_point(algo, n, ops, QueueConfig::default(), 42)
            });
        }
    }
    suite.finish()?;

    // Scaling-law fits through the AOT metrics pipeline (t(n)=n/(a+b·n)).
    let engine = MetricsEngine::auto();
    println!("\nscaling fits (backend={}):", engine.backend());
    for algo in ["perlcrq", "pbqueue", "pwfqueue", "perlcrq-phead"] {
        let pts: Vec<(f64, f64)> = thread_sweep()
            .iter()
            .filter_map(|&n| suite.mean_at(algo, n as f64).map(|y| (n as f64, y)))
            .collect();
        let (ns, ys): (Vec<f64>, Vec<f64>) = pts.into_iter().unzip();
        let fit = engine.fit(&ns, &ys)?;
        println!("  {algo:<16} plateau={:.2} Mops (a={:.3}, b={:.4})", fit.plateau, fit.a, fit.b);
    }

    // Shape assertions (the paper's headline claims).
    let hi = *thread_sweep().last().unwrap() as f64;
    let perlcrq = suite.mean_at("perlcrq", hi).unwrap();
    let pbq = suite.mean_at("pbqueue", hi).unwrap();
    let phead = suite.mean_at("perlcrq-phead", hi).unwrap();
    println!("\nclaims @ {hi} threads:");
    println!("  PerLCRQ/PBQueue = {:.2}x (paper: >= 2x)", perlcrq / pbq);
    println!(
        "  PerLCRQ-PHead ({phead:.2}) below PBQueue ({pbq:.2}): {}",
        phead < pbq
    );
    Ok(())
}
