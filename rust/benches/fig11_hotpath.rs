//! Figure 11 (beyond the paper) — what the epoch-pinned hot path buys
//! over the per-operation plan `RwLock` it replaced.
//!
//! Two series, both on the sharded/batched queue with **no resize in
//! flight** (steady state — the regime the lock was removed for):
//!
//! * **contended** — `THREADS` worker threads run the pairs workload;
//!   wall-clock Mops/s, epoch-pinned vs an `RwLock` baseline that
//!   read-acquires a plan lock around every operation (faithfully
//!   reconstructing the removed hot path: same queue, same workload —
//!   the delta is the lock).
//! * **single-op** — one thread, uncontended; wall-clock ns/op for the
//!   same pair of configurations.
//!
//! Wall time, not simulated time: the simulator charges no virtual cost
//! for volatile synchronization (locks and fences are exactly the
//! overhead the virtual clocks abstract away), so lock removal is
//! invisible in `sim_mops` by construction.
//!
//! Headline claims (checked below; thresholds env-overridable for small
//! shared CI runners):
//!
//! * **steady-state throughput** — epoch-pinned ≥
//!   `PERSIQ_FIG11_MIN_SPEEDUP` (default 1.15) × the RwLock baseline at
//!   `THREADS` ≥ 8 threads;
//! * **single-op latency** — epoch-pinned ns/op ≤ baseline ×
//!   (1 + `PERSIQ_FIG11_LAT_TOL`) (default 0.15): the pin's
//!   store+fence must not cost more than an uncontended lock;
//! * **fig10 steady-state column no-regress** — psyncs/op in steady
//!   state stays within the group-commit budget (≤ 1/B + 1/K with
//!   fig10's margin), and the baseline and epoch runs agree on it (the
//!   synchronization scheme must not move durability points).

#[path = "common/mod.rs"]
mod common;

use std::sync::{Arc, RwLock};

use persiq::harness::bench::{bench_ops, Suite};
use persiq::harness::runner::run_workload;
use persiq::harness::{RunConfig, Workload};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::queues::perlcrq::PerLcrq;
use persiq::queues::sharded::ShardedQueue;
use persiq::queues::{ConcurrentQueue, QueueConfig, QueueError};

/// Contended-series thread count (the claim is "at ≥ 8 threads").
const THREADS: usize = 8;
const SHARDS: usize = 4;
const BATCH: usize = 4;

/// The pre-refactor hot path, reconstructed: every operation
/// read-acquires a plan lock before touching the queue. The inner queue
/// is the epoch-pinned one (there is only one implementation now), so
/// the measured delta is the lock itself — which is exactly the code
/// the refactor deleted, an uncontended-writer `RwLock` read-acquired
/// per op.
struct RwLockBaseline {
    inner: Arc<ShardedQueue<PerLcrq>>,
    plans: RwLock<()>,
}

impl ConcurrentQueue for RwLockBaseline {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        let _plan = self.plans.read().unwrap();
        self.inner.enqueue(tid, item)
    }

    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        let _plan = self.plans.read().unwrap();
        self.inner.dequeue(tid)
    }

    fn name(&self) -> &'static str {
        "sharded-rwlock-baseline"
    }
}

struct Point {
    wall_mops: f64,
    ns_per_op: f64,
    psyncs_per_op: f64,
}

/// One steady-state run (no resize): `nthreads` over the pairs
/// workload, epoch-pinned as-is or wrapped in the RwLock baseline.
fn hot_point(nthreads: usize, ops: u64, baseline: bool, seed: u64) -> Point {
    let qcfg = QueueConfig {
        shards: SHARDS,
        batch: BATCH,
        batch_deq: BATCH,
        ..Default::default()
    };
    let ctx = common::ctx_with(nthreads, qcfg.clone());
    let q = Arc::new(
        ShardedQueue::new_perlcrq(&ctx.topo, nthreads, qcfg).expect("valid bench config"),
    );
    let as_conc: Arc<dyn ConcurrentQueue> = if baseline {
        Arc::new(RwLockBaseline { inner: q, plans: RwLock::new(()) })
    } else {
        q
    };
    let rc = RunConfig {
        nthreads,
        total_ops: ops,
        workload: Workload::Pairs,
        seed,
        ..Default::default()
    };
    let r = run_workload(&ctx.topo, &as_conc, &rc);
    let stats = ctx.topo.stats_total();
    Point {
        wall_mops: r.wall_mops,
        // wall_mops = ops / 1e6 / sec, so ns/op = 1000 / wall_mops.
        ns_per_op: if r.wall_mops > 0.0 { 1e3 / r.wall_mops } else { f64::INFINITY },
        psyncs_per_op: stats.psyncs as f64 / r.ops_done.max(1) as f64,
    }
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "fig11_hotpath",
        "Fig 11: lock-free hot path — epoch-pinned plan access vs the per-op RwLock",
    );
    let ops = bench_ops().max(8_000);

    // Wall-clock comparisons on a shared machine are noisy: keep the
    // best run per side (the least-perturbed sample bounds the true
    // cost from below on both sides of the ratio).
    let mut base_tput: Vec<f64> = Vec::new();
    let mut epoch_tput: Vec<f64> = Vec::new();
    let mut base_lat: Vec<f64> = Vec::new();
    let mut epoch_lat: Vec<f64> = Vec::new();
    let mut psyncs = (0.0f64, 0.0f64); // (baseline, epoch), last sample

    suite.measure_extra("contended-rwlock", THREADS as f64, || {
        let p = hot_point(THREADS, ops, true, 7);
        base_tput.push(p.wall_mops);
        psyncs.0 = p.psyncs_per_op;
        (p.wall_mops, vec![("psyncs/op".to_string(), p.psyncs_per_op)])
    });
    suite.measure_extra("contended-epoch", THREADS as f64, || {
        let p = hot_point(THREADS, ops, false, 7);
        epoch_tput.push(p.wall_mops);
        psyncs.1 = p.psyncs_per_op;
        (p.wall_mops, vec![("psyncs/op".to_string(), p.psyncs_per_op)])
    });
    suite.measure_extra("single-op-rwlock", 1.0, || {
        let p = hot_point(1, ops / 2, true, 11);
        base_lat.push(p.ns_per_op);
        (p.ns_per_op, vec![("psyncs/op".to_string(), p.psyncs_per_op)])
    });
    suite.measure_extra("single-op-epoch", 1.0, || {
        let p = hot_point(1, ops / 2, false, 11);
        epoch_lat.push(p.ns_per_op);
        (p.ns_per_op, vec![("psyncs/op".to_string(), p.psyncs_per_op)])
    });
    let best = |v: &[f64]| v.iter().cloned().fold(f64::NAN, f64::max);
    let least = |v: &[f64]| v.iter().cloned().fold(f64::NAN, f64::min);

    suite.config("threads", THREADS);
    suite.config("shards", SHARDS);
    suite.config("batch", BATCH);
    suite.config("ops", ops);

    // --- Claim 1: contended steady-state throughput ------------------
    let min_speedup = env_f64("PERSIQ_FIG11_MIN_SPEEDUP", 1.15);
    let speedup = best(&epoch_tput) / best(&base_tput);
    suite.claim(
        "fig11-contended-speedup",
        "epoch-pinned plan access beats the per-op RwLock under contention",
        speedup >= min_speedup,
        format!("epoch/rwlock wall speedup = {speedup:.2}x @ {THREADS} threads (bound {min_speedup:.2})"),
    );

    // --- Claim 2: uncontended single-op latency not worse ------------
    let lat_tol = env_f64("PERSIQ_FIG11_LAT_TOL", 0.15);
    let (b, e) = (least(&base_lat), least(&epoch_lat));
    suite.claim(
        "fig11-single-op-latency",
        "the pin's store+fence costs no more than an uncontended lock",
        e <= b * (1.0 + lat_tol),
        format!("epoch {e:.0}ns vs rwlock {b:.0}ns (tolerance x{:.2})", 1.0 + lat_tol),
    );

    // --- Claim 3: fig10 steady-state column no-regress ---------------
    // Same margin fig10 applies to its non-transition windows, against
    // the group-commit budget 1/B (enqueue flushes) + 1/K (dequeue
    // order-log flushes).
    let budget = 1.0 / BATCH as f64 + 1.0 / BATCH as f64;
    suite.claim(
        "fig11-psync-budget",
        "steady-state psyncs/op stays within the group-commit budget",
        psyncs.1 <= budget * 1.10 + 0.02,
        format!("psyncs/op {:.3} vs budget {budget:.3}", psyncs.1),
    );
    suite.claim(
        "fig11-psync-agreement",
        "the synchronization scheme does not move durability points",
        (psyncs.1 - psyncs.0).abs() <= 0.02,
        format!("rwlock {:.3} vs epoch {:.3} psyncs/op", psyncs.0, psyncs.1),
    );

    suite.finish()?;
    anyhow::ensure!(suite.claims_pass(), "fig11 hot-path claims failed");
    Ok(())
}
