//! Figure 5 — "Recovery time of PerIQ as the queue size increases": fill
//! the queue to size S (enqueue-only), crash, measure recovery; pure
//! PerIQ vs the persist-endpoints variant.
//!
//! Expected shape (paper): pure PerIQ's recovery grows with queue size
//! (the Head walk-back crosses the whole live range); the persist variant
//! stays flat (bounded endpoint window).

#[path = "common/mod.rs"]
mod common;

use persiq::harness::bench::Suite;
use persiq::harness::runner::{run_workload, RunConfig};
use persiq::harness::Workload;
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::queues::{persistent_by_name, QueueConfig};
use persiq::util::rng::Xoshiro256;

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "fig5_recovery_size",
        "Fig 5: PerIQ recovery time vs queue size at crash",
    );
    for (series, interval) in [("periq", 0usize), ("periq-ptail", 1usize)] {
        for &size in &[2_000u64, 8_000, 32_000, 128_000] {
            suite.measure(series, size as f64, || {
                let qcfg = QueueConfig {
                    periq_tail_interval: interval,
                    iq_capacity: 1 << 20,
                    ..Default::default()
                };
                let c = common::ctx_with(4, qcfg);
                let q = persistent_by_name("periq").unwrap()(&c);
                let qc: std::sync::Arc<dyn persiq::queues::ConcurrentQueue> =
                    std::sync::Arc::clone(&q) as _;
                // Fill to the target size.
                let r = run_workload(
                    &c.topo,
                    &qc,
                    &RunConfig {
                        nthreads: 4,
                        total_ops: size,
                        workload: Workload::EnqOnly,
                        ..Default::default()
                    },
                );
                assert_eq!(r.ops_done, size);
                let mut rng = Xoshiro256::seed_from(45);
                c.topo.crash(&mut rng);
                c.topo.reset_meter();
                q.recover(c.pool());
                c.topo.vtime(0) as f64 / 1e3 // µs simulated
            });
        }
    }
    suite.finish()?;
    let grow = suite.mean_at("periq", 128_000.0).unwrap()
        / suite.mean_at("periq", 2_000.0).unwrap().max(1e-9);
    let flat = suite.mean_at("periq-ptail", 128_000.0).unwrap()
        / suite.mean_at("periq-ptail", 2_000.0).unwrap().max(1e-9);
    println!("\nclaims: pure grows {grow:.1}x from 2k->128k items; persist-tail {flat:.1}x (paper: pure grows, variant flat)");
    Ok(())
}
