//! Figure 6 — "Throughput of PerIQ and PerIQ (no Tail)": the other half of
//! the Figures 4-6 tradeoff — persisting the endpoints every operation
//! costs normal-execution throughput.
//!
//! Expected shape (paper): pure PerIQ (no endpoint persists) clearly above
//! the per-op persist variant at every thread count.

#[path = "common/mod.rs"]
mod common;

use persiq::harness::bench::{bench_ops, thread_sweep, Suite};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::queues::QueueConfig;

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let mut suite = Suite::new(
        "fig6_periq_tradeoff",
        "Fig 6: PerIQ throughput — persist endpoints per op vs never",
    );
    let ops = bench_ops();
    for (series, interval) in [("periq", 0usize), ("periq-ptail", 1usize)] {
        for &n in &thread_sweep() {
            let qcfg = QueueConfig {
                periq_tail_interval: interval,
                iq_capacity: (ops as usize * 2).next_power_of_two(),
                ..Default::default()
            };
            suite.measure(series, n as f64, || {
                common::tput_point("periq", n, ops, qcfg.clone(), 46)
            });
        }
    }
    suite.finish()?;
    let hi = *thread_sweep().last().unwrap() as f64;
    let pure = suite.mean_at("periq", hi).unwrap();
    let ptail = suite.mean_at("periq-ptail", hi).unwrap();
    println!("\nclaims @ {hi} threads: pure/persist-tail = {:.2}x (paper: > 1)", pure / ptail);
    Ok(())
}
