//! Shared bench scaffolding (each bench target includes this by `#[path]`).
//!
//! Each bench target compiles this module independently and uses a
//! different helper subset — silence per-target dead-code noise once.
#![allow(dead_code)]

use persiq::config::Config;
use persiq::harness::runner::{run_workload, RunConfig};
use persiq::harness::Workload;
use persiq::pmem::Topology;
use persiq::queues::{by_name, QueueConfig, QueueCtx};

/// Build a queue context with the given thread count + queue config
/// (single-pool topology).
pub fn ctx_with(nthreads: usize, qcfg: QueueConfig) -> QueueCtx {
    ctx_with_pools(nthreads, qcfg, 1)
}

/// Build a queue context over an `npools`-socket topology.
pub fn ctx_with_pools(nthreads: usize, qcfg: QueueConfig, npools: usize) -> QueueCtx {
    let mut cfg = Config::load_default();
    cfg.queue = qcfg;
    QueueCtx { topo: Topology::new(cfg.pmem.clone(), npools), nthreads, cfg: cfg.queue }
}

/// One throughput point: run `algo` and return simulated Mops/s.
pub fn tput_point(algo: &str, nthreads: usize, ops: u64, qcfg: QueueConfig, seed: u64) -> f64 {
    let c = ctx_with(nthreads, qcfg);
    let q = by_name(algo).unwrap_or_else(|| panic!("unknown algo {algo}"))(&c);
    let r = run_workload(
        &c.topo,
        &q,
        &RunConfig { nthreads, total_ops: ops, workload: Workload::Pairs, seed, ..Default::default() },
    );
    r.sim_mops
}

/// Throughput + persistence-instruction counts per op.
pub fn tput_point_extra(
    algo: &str,
    nthreads: usize,
    ops: u64,
    qcfg: QueueConfig,
    seed: u64,
) -> (f64, Vec<(String, f64)>) {
    let c = ctx_with(nthreads, qcfg);
    let q = by_name(algo).unwrap_or_else(|| panic!("unknown algo {algo}"))(&c);
    let r = run_workload(
        &c.topo,
        &q,
        &RunConfig { nthreads, total_ops: ops, workload: Workload::Pairs, seed, ..Default::default() },
    );
    let t = c.topo.stats_total();
    let per = |x: u64| x as f64 / r.ops_done.max(1) as f64;
    (
        r.sim_mops,
        vec![
            ("pwbs/op".to_string(), per(t.pwbs)),
            ("psyncs/op".to_string(), per(t.psyncs)),
        ],
    )
}
