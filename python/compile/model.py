"""L2 — the JAX metrics pipeline (the paper's §5 evaluation analytics).

``metrics(samples)`` turns a fixed-shape matrix of per-operation latency
samples (simulated ns; negative = padding) into the statistics every figure
reports:

    stats = [count, mean, std, min, max, p50, p95, p99]
    hist  = 64-bucket histogram over [min, max)

The single data pass (histogram + moments) is the L1 Pallas kernel
(`kernels.stats`); quantiles come from the histogram CDF; everything is one
jitted function so AOT lowering produces a single fused HLO module that the
Rust runtime executes via PJRT (python never runs at request/analysis time).

``fit_scaling(ns, tputs)`` is the second, tiny pipeline: a closed-form
least-squares fit of the saturating throughput model  t(n) = n / (a + b·n)
(linearized as n/t = a + b·n), used to summarize scaling curves; exported in
the same artifact bundle.
"""

import jax
import jax.numpy as jnp

from compile.kernels import stats as kstats

# AOT export geometry: 64x128 = 8192 samples per call.
ROWS = 64
COLS = kstats.COLS
NBINS = kstats.NBINS


def metrics(samples: jax.Array):
    """Aggregate a (ROWS, COLS) f32 latency matrix; see module docstring."""
    valid = samples >= 0.0
    big = jnp.float32(3.4e38)
    mn = jnp.min(jnp.where(valid, samples, big))
    mx = jnp.max(jnp.where(valid, samples, -big))
    # Guard degenerate ranges (all-equal or empty): width >= tiny.
    width = jnp.maximum(mx - mn, jnp.float32(1e-6))
    # Normalize to [0, 1); keep padding negative.
    norm = jnp.where(valid, (samples - mn) / (width * (1.0 + 1e-6)), -1.0)

    hist, mom = kstats.histogram_moments(norm, NBINS)

    count = mom[0]
    safe_count = jnp.maximum(count, 1.0)
    mean_n = mom[1] / safe_count
    var_n = jnp.maximum(mom[2] / safe_count - mean_n * mean_n, 0.0)
    mean = mn + mean_n * width
    std = jnp.sqrt(var_n) * width

    # Quantiles from the histogram CDF (bucket upper edges).
    cdf = jnp.cumsum(hist)
    edges = mn + (jnp.arange(NBINS, dtype=jnp.float32) + 1.0) / NBINS * width

    def quantile(p):
        target = p * count
        idx = jnp.searchsorted(cdf, target)
        return edges[jnp.clip(idx, 0, NBINS - 1)]

    p50, p95, p99 = quantile(0.50), quantile(0.95), quantile(0.99)
    out_stats = jnp.stack([count, mean, std, mn, mx, p50, p95, p99])
    return out_stats, hist


def fit_scaling(ns: jax.Array, tputs: jax.Array):
    """Fit t(n) = n / (a + b·n) by least squares on n/t = a + b·n.

    Inputs are fixed-length (16) f32 vectors; entries with tput <= 0 are
    masked out. Returns [a, b, plateau] where plateau = 1/b is the
    saturation throughput.
    """
    valid = tputs > 0.0
    w = valid.astype(jnp.float32)
    y = jnp.where(valid, ns / jnp.maximum(tputs, 1e-9), 0.0)
    n = jnp.maximum(jnp.sum(w), 1.0)
    sx = jnp.sum(w * ns)
    sy = jnp.sum(w * y)
    sxx = jnp.sum(w * ns * ns)
    sxy = jnp.sum(w * ns * y)
    denom = n * sxx - sx * sx
    b = jnp.where(jnp.abs(denom) > 1e-9, (n * sxy - sx * sy) / denom, 0.0)
    a = (sy - b * sx) / n
    plateau = jnp.where(jnp.abs(b) > 1e-12, 1.0 / b, 0.0)
    return jnp.stack([a, b, plateau])


def metrics_spec():
    """Example-arg spec for AOT lowering of ``metrics``."""
    return (jax.ShapeDtypeStruct((ROWS, COLS), jnp.float32),)


def fit_spec():
    """Example-arg spec for AOT lowering of ``fit_scaling``."""
    return (
        jax.ShapeDtypeStruct((16,), jnp.float32),
        jax.ShapeDtypeStruct((16,), jnp.float32),
    )
