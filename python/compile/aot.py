"""AOT lowering: JAX (L2+L1) -> HLO *text* -> artifacts/ for the Rust
runtime.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits StableHLO/protos with 64-bit instruction ids which
the image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts/metrics.hlo.txt

Produces, next to ``--out``:
  metrics.hlo.txt  — metrics(samples[64,128]) -> (stats[8], hist[64])
  fit.hlo.txt      — fit_scaling(ns[16], tput[16]) -> [a, b, plateau]
  manifest.txt     — shapes/targets, consumed by rust/src/runtime.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/metrics.hlo.txt")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)

    metrics_path = args.out
    fit_path = os.path.join(out_dir, "fit.hlo.txt")
    manifest_path = os.path.join(out_dir, "manifest.txt")

    text = to_hlo_text(model.metrics, model.metrics_spec())
    with open(metrics_path, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {metrics_path}")

    text = to_hlo_text(model.fit_scaling, model.fit_spec())
    with open(fit_path, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {fit_path}")

    with open(manifest_path, "w") as f:
        f.write(
            "# persiq AOT artifact manifest (format v1)\n"
            f"metrics.hlo.txt metrics in=f32[{model.ROWS},{model.COLS}] "
            "out=(f32[8],f32[64])\n"
            "fit.hlo.txt fit_scaling in=(f32[16],f32[16]) out=f32[3]\n"
        )
    print(f"wrote manifest to {manifest_path}")


if __name__ == "__main__":
    main()
