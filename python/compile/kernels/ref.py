"""Pure-jnp oracle for the L1 kernel — the CORE correctness signal.

``histogram_moments_ref`` must match ``stats.histogram_moments`` in
binning/clipping/padding semantics so pytest can assert ``allclose`` over
randomized shapes and contents.
"""

import jax.numpy as jnp


def histogram_moments_ref(x, nbins: int = 64):
    """Reference histogram + moments (see stats.histogram_moments)."""
    valid = x >= 0.0
    xv = jnp.where(valid, x, 0.0)
    count = jnp.sum(valid.astype(jnp.float32))
    s = jnp.sum(xv)
    sq = jnp.sum(xv * xv)
    mn = jnp.min(jnp.where(valid, x, jnp.inf))
    mx = jnp.max(jnp.where(valid, x, -jnp.inf))
    bins = jnp.clip((x * nbins).astype(jnp.int32), 0, nbins - 1)
    # Scatter-add via one-hot (matches the kernel's semantics exactly).
    onehot = (bins[..., None] == jnp.arange(nbins, dtype=jnp.int32)).astype(jnp.float32)
    hist = jnp.sum(jnp.where(valid[..., None], onehot, 0.0), axis=tuple(range(x.ndim)))
    moments = jnp.stack(
        [count, s, sq, mn, mx, jnp.float32(0), jnp.float32(0), jnp.float32(0)]
    )
    return hist, moments
