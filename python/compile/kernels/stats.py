"""L1 — Pallas kernel: tiled histogram + moments over latency samples.

The evaluation pipeline's compute hot-spot (DESIGN.md §2): every figure of
the paper's §5 is produced by aggregating per-operation latency samples into
histograms, moments and quantiles. This kernel performs the single data
pass: it streams sample tiles and accumulates

* a ``NBINS``-bucket histogram of samples normalized to ``[0, 1)``,
* ``count`` (valid samples), ``sum``, ``sum of squares``, ``min``, ``max``.

Padding convention: invalid/padding entries are negative (callers use
``-1.0``); they contribute to nothing.

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernel is a
bandwidth-bound reduction — BlockSpec tiles of ``(TILE_ROWS, COLS) =
(8, 128)`` f32 match the VPU lane layout, stream HBM→VMEM once, and keep
the (NBINS + 8)-word accumulator resident in VMEM across grid steps
(revisited output block). The MXU is unused (no matmuls); the roofline is
the VPU compare/add rate. ``interpret=True`` is required for CPU-PJRT
execution (real TPU lowering emits a Mosaic custom-call the CPU plugin
cannot run).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed kernel geometry (AOT artifacts export these shapes).
NBINS = 64
TILE_ROWS = 8
COLS = 128


def _kernel(x_ref, hist_ref, mom_ref, *, nbins: int):
    """One grid step: accumulate a (TILE_ROWS, COLS) tile."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        mom_ref[...] = jnp.zeros_like(mom_ref)
        # min identity = +inf, max identity = -inf (slots 3, 4).
        mom_ref[3] = jnp.inf
        mom_ref[4] = -jnp.inf

    x = x_ref[...]
    valid = x >= 0.0
    xv = jnp.where(valid, x, 0.0)

    # Moments.
    mom_ref[0] += jnp.sum(valid.astype(jnp.float32))
    mom_ref[1] += jnp.sum(xv)
    mom_ref[2] += jnp.sum(xv * xv)
    mom_ref[3] = jnp.minimum(mom_ref[3], jnp.min(jnp.where(valid, x, jnp.inf)))
    mom_ref[4] = jnp.maximum(mom_ref[4], jnp.max(jnp.where(valid, x, -jnp.inf)))

    # Histogram over [0, 1): bin = floor(x * nbins), clipped into range.
    bins = jnp.clip((x * nbins).astype(jnp.int32), 0, nbins - 1)
    # One-hot accumulate: (T, C, 1) == (nbins,) -> sum over tile dims.
    onehot = (bins[..., None] == jnp.arange(nbins, dtype=jnp.int32)[None, None, :])
    contrib = jnp.sum(
        jnp.where(valid[..., None], onehot.astype(jnp.float32), 0.0), axis=(0, 1)
    )
    hist_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("nbins",))
def histogram_moments(x: jax.Array, nbins: int = NBINS):
    """Tiled histogram + moments of ``x`` (shape ``(rows, COLS)``, rows a
    multiple of ``TILE_ROWS``; values in ``[0, 1)`` or negative padding).

    Returns ``(hist[nbins] f32, moments[8] f32)`` with moments
    ``[count, sum, sumsq, min, max, 0, 0, 0]``.
    """
    rows, cols = x.shape
    if cols != COLS:
        raise ValueError(f"cols must be {COLS}, got {cols}")
    if rows % TILE_ROWS != 0:
        raise ValueError(f"rows must be a multiple of {TILE_ROWS}, got {rows}")
    grid = rows // TILE_ROWS
    return pl.pallas_call(
        functools.partial(_kernel, nbins=nbins),
        grid=(grid,),
        in_specs=[pl.BlockSpec((TILE_ROWS, COLS), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((nbins,), lambda i: (0,)),
            pl.BlockSpec((8,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbins,), jnp.float32),
            jax.ShapeDtypeStruct((8,), jnp.float32),
        ],
        interpret=True,  # CPU-PJRT execution path; see module docstring
    )(x)


def vmem_footprint_bytes(nbins: int = NBINS) -> int:
    """Estimated VMEM residency per grid step (DESIGN/EXPERIMENTS §Perf):
    one input tile + both accumulators, f32."""
    tile = TILE_ROWS * COLS * 4
    accum = (nbins + 8) * 4
    # One-hot intermediate is fused on TPU; worst-case materialization:
    onehot = TILE_ROWS * COLS * nbins * 4
    return tile + accum + onehot
