"""AOT path: lowering produces parseable HLO text + manifest."""

import os
import subprocess
import sys


def test_aot_writes_artifacts(tmp_path):
    out = tmp_path / "metrics.hlo.txt"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    text = out.read_text()
    assert text.startswith("HloModule")
    assert "f32[64,128]" in text, "metrics input shape must appear in HLO"
    fit = (tmp_path / "fit.hlo.txt").read_text()
    assert fit.startswith("HloModule")
    manifest = (tmp_path / "manifest.txt").read_text()
    assert "metrics.hlo.txt" in manifest
    assert "fit.hlo.txt" in manifest


def test_hlo_text_has_no_serialized_proto_markers():
    # Guard the interchange contract: we ship text, not serialized protos
    # (xla_extension 0.5.1 rejects jax>=0.5 protos — see aot.py docstring).
    art = os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
        "artifacts",
        "metrics.hlo.txt",
    )
    if not os.path.exists(art):
        import pytest

        pytest.skip("artifacts not built")
    head = open(art).read(64)
    assert head.startswith("HloModule"), "artifact must be HLO text"
