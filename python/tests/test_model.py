"""L2 metrics pipeline vs numpy oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.model import COLS, ROWS, fit_scaling, metrics


def pad_to_shape(samples: np.ndarray) -> np.ndarray:
    out = np.full((ROWS, COLS), -1.0, dtype=np.float32)
    flat = samples.astype(np.float32).ravel()[: ROWS * COLS]
    out.ravel()[: flat.size] = flat
    return out


def np_stats(samples: np.ndarray):
    return dict(
        count=samples.size,
        mean=samples.mean(),
        std=samples.std(),
        mn=samples.min(),
        mx=samples.max(),
        p50=np.percentile(samples, 50),
        p95=np.percentile(samples, 95),
        p99=np.percentile(samples, 99),
    )


def test_metrics_against_numpy():
    rng = np.random.default_rng(0)
    samples = (rng.random(5000) * 800 + 100).astype(np.float32)  # 100..900ns
    s, hist = metrics(jnp.asarray(pad_to_shape(samples)))
    s = np.asarray(s)
    ref = np_stats(samples)
    assert s[0] == ref["count"]
    np.testing.assert_allclose(s[1], ref["mean"], rtol=1e-3)
    np.testing.assert_allclose(s[2], ref["std"], rtol=1e-2)
    np.testing.assert_allclose(s[3], ref["mn"], rtol=1e-5)
    np.testing.assert_allclose(s[4], ref["mx"], rtol=1e-5)
    # Histogram quantiles: within one bucket width of exact.
    width = (ref["mx"] - ref["mn"]) / 64
    for i, p in [(5, "p50"), (6, "p95"), (7, "p99")]:
        assert abs(s[i] - ref[p]) <= width * 1.5, (p, s[i], ref[p])
    assert np.asarray(hist).sum() == ref["count"]


def test_metrics_degenerate_constant():
    samples = np.full(100, 42.0, dtype=np.float32)
    s, hist = metrics(jnp.asarray(pad_to_shape(samples)))
    s = np.asarray(s)
    assert s[0] == 100
    np.testing.assert_allclose(s[1], 42.0, rtol=1e-5)
    np.testing.assert_allclose(s[2], 0.0, atol=1e-3)


def test_metrics_empty():
    s, hist = metrics(jnp.full((ROWS, COLS), -1.0, dtype=jnp.float32))
    assert np.asarray(s)[0] == 0


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=10, max_value=ROWS * COLS),
    seed=st.integers(0, 2**31),
    lo=st.floats(min_value=0.0, max_value=1e4),
    span=st.floats(min_value=1.0, max_value=1e6),
)
def test_hypothesis_mean_std(n, seed, lo, span):
    rng = np.random.default_rng(seed)
    samples = (rng.random(n) * span + lo).astype(np.float32)
    s, _ = metrics(jnp.asarray(pad_to_shape(samples)))
    s = np.asarray(s)
    assert s[0] == n
    np.testing.assert_allclose(s[1], samples.mean(), rtol=5e-3)
    assert s[3] <= s[5] <= s[7] <= s[4] + 1e-3  # min <= p50 <= p99 <= max


def test_fit_scaling_recovers_model():
    # Ground truth t(n) = n / (a + b n) with a=2, b=0.05 -> plateau 20.
    ns = np.arange(1, 17, dtype=np.float32)
    t = ns / (2.0 + 0.05 * ns)
    out = np.asarray(fit_scaling(jnp.asarray(ns), jnp.asarray(t)))
    np.testing.assert_allclose(out[0], 2.0, rtol=1e-3)
    np.testing.assert_allclose(out[1], 0.05, rtol=1e-3)
    np.testing.assert_allclose(out[2], 20.0, rtol=1e-3)


def test_fit_scaling_masks_invalid():
    ns = np.arange(1, 17, dtype=np.float32)
    t = ns / (1.0 + 0.1 * ns)
    t[10:] = 0.0  # masked
    out = np.asarray(fit_scaling(jnp.asarray(ns), jnp.asarray(t)))
    np.testing.assert_allclose(out[1], 0.1, rtol=1e-3)
