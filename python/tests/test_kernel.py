"""L1 kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps tile counts, value distributions, padding patterns and
bin counts; every case asserts allclose between the Pallas kernel
(interpret=True) and the reference.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import histogram_moments_ref
from compile.kernels.stats import (
    COLS,
    NBINS,
    TILE_ROWS,
    histogram_moments,
    vmem_footprint_bytes,
)


def run_both(x, nbins=NBINS):
    h1, m1 = histogram_moments(jnp.asarray(x), nbins)
    h2, m2 = histogram_moments_ref(jnp.asarray(x), nbins)
    return np.asarray(h1), np.asarray(m1), np.asarray(h2), np.asarray(m2)


def assert_match(x, nbins=NBINS):
    h1, m1, h2, m2 = run_both(x, nbins)
    np.testing.assert_allclose(h1, h2, rtol=1e-6, atol=0)
    np.testing.assert_allclose(m1, m2, rtol=1e-5, atol=1e-6)


def test_basic_uniform():
    x = np.random.default_rng(0).random((TILE_ROWS, COLS), dtype=np.float32)
    assert_match(x)


def test_multi_tile():
    x = np.random.default_rng(1).random((8 * TILE_ROWS, COLS), dtype=np.float32)
    assert_match(x)


def test_all_padding():
    x = np.full((TILE_ROWS, COLS), -1.0, dtype=np.float32)
    h1, m1, _, _ = run_both(x)
    assert m1[0] == 0.0
    assert h1.sum() == 0.0


def test_single_value_spikes_one_bin():
    x = np.full((TILE_ROWS, COLS), 0.5, dtype=np.float32)
    h1, m1, h2, m2 = run_both(x)
    assert h1.sum() == TILE_ROWS * COLS
    assert (h1 > 0).sum() == 1
    np.testing.assert_allclose(h1, h2)


def test_values_at_edges_clip():
    # 0.0 lands in bin 0; >= 1.0 clips into the last bin.
    x = np.zeros((TILE_ROWS, COLS), dtype=np.float32)
    x[0, 0] = 1.0
    x[0, 1] = 0.999999
    h1, m1, h2, m2 = run_both(x)
    np.testing.assert_allclose(h1, h2)
    assert h1[0] == TILE_ROWS * COLS - 2
    assert h1[NBINS - 1] == 2


def test_histogram_total_equals_count():
    rng = np.random.default_rng(3)
    x = rng.random((2 * TILE_ROWS, COLS), dtype=np.float32)
    x[rng.random(x.shape) < 0.3] = -1.0
    h1, m1, _, _ = run_both(x)
    assert h1.sum() == m1[0]


def test_shape_validation():
    with pytest.raises(ValueError):
        histogram_moments(jnp.zeros((TILE_ROWS, COLS + 1), jnp.float32))
    with pytest.raises(ValueError):
        histogram_moments(jnp.zeros((TILE_ROWS + 1, COLS), jnp.float32))


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31),
    pad_frac=st.floats(min_value=0.0, max_value=0.9),
    scale=st.sampled_from([1.0, 0.5, 0.01]),
)
def test_hypothesis_sweep(tiles, seed, pad_frac, scale):
    rng = np.random.default_rng(seed)
    x = (rng.random((tiles * TILE_ROWS, COLS)) * scale).astype(np.float32)
    x[rng.random(x.shape) < pad_frac] = -1.0
    assert_match(x)


@settings(max_examples=10, deadline=None)
@given(nbins=st.sampled_from([8, 16, 64, 128]), seed=st.integers(0, 2**31))
def test_hypothesis_bin_counts(nbins, seed):
    x = np.random.default_rng(seed).random((TILE_ROWS, COLS), dtype=np.float32)
    assert_match(x, nbins)


def test_vmem_footprint_estimate_reasonable():
    # Perf documentation helper: must fit comfortably in 16 MiB VMEM.
    assert vmem_footprint_bytes() < 16 * 1024 * 1024
