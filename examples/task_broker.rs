//! End-to-end driver (EXPERIMENTS.md §E2E): the persistent task-broker
//! service on PerLCRQ, run on a real workload with crash/recovery cycles,
//! reporting throughput and latency through the AOT-compiled JAX/Pallas
//! metrics pipeline executed via PJRT (build `artifacts/` first with
//! `make artifacts`; falls back to pure Rust with a warning otherwise).
//!
//! ```sh
//! cargo run --release --example task_broker -- [jobs-per-producer] [crash-cycles]
//! ```

use std::sync::Arc;

use persiq::coordinator::{run_service, Broker, ServiceConfig};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::{PmemConfig, Topology};
use persiq::runtime::MetricsEngine;
use persiq::util::report::fnum;

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let jobs: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2_000);
    let crash_cycles: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3);

    let producers = 2;
    let workers = 2;
    let topo = Topology::single(PmemConfig::default().with_capacity(1 << 24));
    let broker = Arc::new(Broker::new_on(&topo, producers + workers, 1 << 18, 1 << 10));

    println!(
        "task broker: {producers} producers x {jobs} jobs, {workers} workers, \
         {crash_cycles} crash/recovery cycles"
    );
    let rep = run_service(
        &topo,
        &broker,
        &ServiceConfig {
            producers,
            workers,
            jobs_per_producer: jobs,
            crash_cycles,
            crash_steps: 400_000,
            seed: 7,
            ..Default::default()
        },
    )?;

    println!("\n== results ==");
    println!("submitted : {}", rep.submitted);
    println!("done      : {}", rep.done);
    println!("pending   : {}", rep.pending_after);
    println!("crashes   : {}", rep.crashes);
    println!("wall time : {:.3}s", rep.wall_secs);
    println!(
        "throughput: {:.1}k jobs/s (wall)",
        rep.done as f64 / rep.wall_secs / 1e3
    );

    // Analyze job latencies through the L1/L2 pipeline (PJRT).
    let engine = MetricsEngine::auto();
    let m = engine.metrics(&rep.latency_samples)?;
    println!("\n== job latency (simulated ns, backend={}) ==", m.backend);
    println!(
        "count={} mean={} p50={} p95={} p99={} max={}",
        m.count,
        fnum(m.mean),
        fnum(m.p50),
        fnum(m.p95),
        fnum(m.p99),
        fnum(m.max)
    );

    // The e2e invariant: nothing lost, nothing double-completed.
    anyhow::ensure!(rep.done == rep.submitted, "JOB LOSS: {rep:?}");
    anyhow::ensure!(rep.pending_after == 0, "unfinished jobs: {rep:?}");
    println!("\nOK: every durably submitted job completed exactly once across {} crashes.", rep.crashes);
    Ok(())
}
