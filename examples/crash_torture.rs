//! Crash-torture: hammer every persistent queue with random mid-operation
//! crashes and verify durable linearizability (V1-V5, verify/checker.rs)
//! across every cycle. This is the §5 failure framework exercised as an
//! acceptance gate (experiment V1 in DESIGN.md).
//!
//! ```sh
//! cargo run --release --example crash_torture -- [cycles] [seed]
//! ```

use std::sync::Arc;

use persiq::harness::runner::{drain_all, run_workload, RunConfig};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::{PmemConfig, Topology};
use persiq::queues::{persistent_registry, QueueConfig, QueueCtx};
use persiq::util::rng::Xoshiro256;
use persiq::verify::{check_relaxed, relaxation_for, History};

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cycles: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(6);
    let seed: u64 =
        args.get(1).and_then(|s| s.parse().ok()).unwrap_or_else(persiq::util::rng::entropy_seed);
    println!("crash torture: {cycles} cycles per algorithm, seed={seed}");

    let nthreads = 4;
    let mut failures = 0;
    for (name, ctor) in persistent_registry() {
        let ctx = QueueCtx {
            topo: Topology::single(PmemConfig::default().with_capacity(1 << 23)),
            nthreads,
            cfg: QueueConfig::default(),
        };
        let q = ctor(&ctx);
        let qc: Arc<dyn persiq::queues::ConcurrentQueue> = Arc::clone(&q) as _;
        let mut rng = Xoshiro256::split(seed, 99);
        let mut logs = Vec::new();
        for cycle in 0..cycles {
            ctx.topo.arm_crash_after(20_000 + rng.next_below(30_000));
            let r = run_workload(
                &ctx.topo,
                &qc,
                &RunConfig {
                    nthreads,
                    total_ops: 60_000,
                    record: true,
                    salt: cycle as u64 + 1,
                    seed: seed ^ ((cycle as u64) << 13),
                    ..Default::default()
                },
            );
            logs.extend(r.logs);
            ctx.topo.crash(&mut rng);
            q.recover(ctx.pool());
        }
        let drained = drain_all(&qc, 0);
        let h = History::from_logs(logs, drained);
        let rep = check_relaxed(&h, relaxation_for(name, nthreads, &ctx.cfg));
        println!(
            "{} {name:<16} ops: enq={} deq={} empty={} drained={} | violations: {}",
            if rep.ok() { "PASS" } else { "FAIL" },
            rep.enq_completed,
            rep.deq_values,
            rep.deq_empties,
            rep.drained,
            rep.violations.len()
        );
        for v in &rep.violations {
            println!("      {v:?}");
            failures += 1;
        }
    }
    anyhow::ensure!(failures == 0, "{failures} durable-linearizability violations");
    println!("\nall persistent queues pass durable-linearizability torture.");
    Ok(())
}
