//! Quickstart: a persistent FIFO queue in ten lines — enqueue, crash,
//! recover, and find everything still there.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use persiq::pmem::{PmemConfig, PmemPool};
use persiq::queues::perlcrq::PerLcrq;
use persiq::queues::{ConcurrentQueue, PersistentQueue, QueueConfig};
use persiq::util::rng::Xoshiro256;

fn main() -> anyhow::Result<()> {
    // A simulated-NVM pool (see DESIGN.md §1 for the model).
    let pool = Arc::new(PmemPool::new(PmemConfig::default()));

    // The paper's PerLCRQ: one pwb+psync per operation, on low-contention
    // locations.
    let q = PerLcrq::new(&pool, /* threads */ 2, QueueConfig::default());

    println!("enqueueing 1..=10 ...");
    for v in 1..=10u64 {
        q.enqueue(0, v)?;
    }
    println!("dequeued {:?} and {:?}", q.dequeue(1)?, q.dequeue(1)?);

    // Full-system crash: volatile state is lost; only persisted (or
    // nondeterministically evicted) lines survive.
    println!("simulating a full-system crash ...");
    let mut rng = Xoshiro256::seed_from(2024);
    pool.crash(&mut rng);

    // The paper's recovery function (Algorithm 3 lines 58-83 per ring +
    // Algorithm 5 list walk).
    q.recover(&pool);
    println!("recovered; draining:");

    let mut drained = Vec::new();
    while let Some(v) = q.dequeue(0)? {
        drained.push(v);
    }
    println!("  {drained:?}");
    assert_eq!(drained, (3..=10).collect::<Vec<u64>>(), "items 3..=10 must survive");
    println!("all completed operations survived the crash — durably linearizable.");
    Ok(())
}
