//! Interactive explorer for the paper's §5 persistence/recovery tradeoff
//! (contribution 2): sweep PerIQ's endpoint-persist interval and print
//! throughput vs recovery cost side by side.
//!
//! ```sh
//! cargo run --release --example tradeoff_explorer -- [ops] [intervals...]
//! # e.g.: cargo run --release --example tradeoff_explorer -- 60000 1 10 100 0
//! ```

use std::sync::Arc;

use persiq::harness::failure::{mean_recovery_sim_ns, run_cycles, CycleConfig};
use persiq::harness::runner::{run_workload, RunConfig};
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::PmemConfig;
use persiq::queues::{persistent_by_name, QueueConfig, QueueCtx};
use persiq::util::report::{fnum, Csv};

fn main() -> anyhow::Result<()> {
    install_quiet_crash_hook();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let ops: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(60_000);
    let intervals: Vec<usize> = if args.len() > 1 {
        args[1..].iter().filter_map(|s| s.parse().ok()).collect()
    } else {
        vec![1, 10, 100, 1000, 0]
    };

    println!("PerIQ persistence/recovery tradeoff (ops={ops}; 0 = never persist endpoints)\n");
    let mut csv = Csv::new(vec!["interval", "throughput_mops", "recovery_us", "recovery_loads"]);
    for &k in &intervals {
        let qcfg =
            QueueConfig { periq_tail_interval: k, iq_capacity: 1 << 20, ..Default::default() };
        // Throughput leg.
        let ctx = QueueCtx::single(
            PmemConfig::default().with_capacity(1 << 23),
            8,
            qcfg.clone(),
        );
        let q = persistent_by_name("periq").unwrap()(&ctx);
        let qc: Arc<dyn persiq::queues::ConcurrentQueue> = Arc::clone(&q) as _;
        let r = run_workload(
            &ctx.topo,
            &qc,
            &RunConfig { nthreads: 8, total_ops: ops, ..Default::default() },
        );
        // Recovery leg (fresh pool; 3 cycles).
        let ctx2 = QueueCtx::single(
            PmemConfig::default().with_capacity(1 << 23),
            4,
            qcfg,
        );
        let q2 = persistent_by_name("periq").unwrap()(&ctx2);
        let res = run_cycles(
            &ctx2.topo,
            &q2,
            &CycleConfig {
                cycles: 3,
                steps: 150_000,
                run: RunConfig { nthreads: 4, total_ops: u64::MAX / 2, ..Default::default() },
                seed: 3,
            },
        );
        let loads: f64 =
            res.iter().map(|c| c.recovery_loads as f64).sum::<f64>() / res.len() as f64;
        csv.row(vec![
            if k == 0 { "never".to_string() } else { k.to_string() },
            fnum(r.sim_mops),
            fnum(mean_recovery_sim_ns(&res) / 1e3),
            fnum(loads),
        ]);
    }
    print!("{}", csv.to_table());
    println!("\nreading: small interval = cheap recovery but slower ops; 'never' = fastest ops, recovery scans the array (Figs 4-6).");
    Ok(())
}
